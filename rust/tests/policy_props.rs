//! Property tests on the SLO policy layer (DESIGN.md §7), using the
//! in-tree testkit::prop framework.
//!
//! Pure properties (no artifacts needed — fast):
//! * the selector never routes a deadlined request to a pool whose
//!   margin-adjusted prediction exceeds the budget, and only sheds when
//!   no pool with queue room fits;
//! * the response cache is a true bounded LRU: hits return the exact
//!   inserted bits, capacity is a hard bound;
//! * the worker's shed-and-serve loop (urgency sort + expiry partition +
//!   batch split) disposes of every admitted request exactly once —
//!   nothing is silently dropped;
//! * urgency sorting drains strictly by (priority, deadline) order.
//!
//! Plus coordinator-level end-to-end versions of the drop and
//! cache-identity invariants against a real engine when artifacts exist.

use std::time::{Duration, Instant};

use zuluko::coordinator::batcher::BatchPolicy;
use zuluko::coordinator::queue::BoundedQueue;
use zuluko::engine::EngineKind;
use zuluko::policy::{
    CachedResult, Decision, LatencyPredictor, PoolView, Priority, ResponseCache,
    Selector, Slo, Urgency,
};
use zuluko::testkit::prop::{prop_check, Gen};
use zuluko::testkit::rng::Rng;

// ---------------------------------------------------------------------------
// Selector: never pick an engine predicted to blow the deadline when an
// alternative fits; shed only when nothing fits.
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
struct SelectorCase {
    acl_ms: f64,
    quant_ms: f64,
    acl_queued: usize,
    quant_queued: usize,
    budget_ms: f64,
    margin: f64,
}

struct GenSelectorCase;

impl Gen for GenSelectorCase {
    type Value = SelectorCase;
    fn generate(&self, rng: &mut Rng) -> SelectorCase {
        SelectorCase {
            acl_ms: rng.uniform(50.0, 600.0),
            quant_ms: rng.uniform(20.0, 300.0),
            acl_queued: rng.range(0, 10),
            quant_queued: rng.range(0, 10),
            budget_ms: rng.uniform(10.0, 1200.0),
            margin: rng.uniform(1.0, 1.5),
        }
    }
    fn shrink(&self, v: &SelectorCase) -> Vec<SelectorCase> {
        let mut out = Vec::new();
        if v.acl_queued > 0 {
            out.push(SelectorCase { acl_queued: 0, ..v.clone() });
        }
        if v.quant_queued > 0 {
            out.push(SelectorCase { quant_queued: 0, ..v.clone() });
        }
        if v.margin > 1.0 {
            out.push(SelectorCase { margin: 1.0, ..v.clone() });
        }
        out
    }
}

#[test]
fn prop_selector_admits_only_within_budget() {
    prop_check(500, 29, GenSelectorCase, |case| {
        let pred = LatencyPredictor::new(0.2);
        pred.record(EngineKind::AclStaged, 1, case.acl_ms);
        pred.record(EngineKind::Quant, 1, case.quant_ms);
        let pools = vec![
            PoolView {
                kind: EngineKind::AclStaged,
                queued: case.acl_queued,
                workers: 1,
                capacity: 8,
            },
            PoolView {
                kind: EngineKind::Quant,
                queued: case.quant_queued,
                workers: 1,
                capacity: 8,
            },
        ];
        let sel = Selector::new(case.margin, 1);
        let slo = Slo::with_deadline_ms(case.budget_ms);
        let fits: Vec<bool> = pools
            .iter()
            .map(|p| {
                p.queued < p.capacity && sel.predict_ms(&pred, p) <= case.budget_ms
            })
            .collect();
        match sel.choose(&pred, &pools, &slo, Some(case.budget_ms)) {
            Decision::Route { pool, predicted_ms } => {
                if predicted_ms > case.budget_ms {
                    return Err(format!(
                        "routed to pool {pool} predicted {predicted_ms:.0}ms \
                         over budget {:.0}ms",
                        case.budget_ms
                    ));
                }
                if !fits[pool] {
                    return Err(format!("routed to non-fitting pool {pool}"));
                }
            }
            Decision::Shed { .. } => {
                if fits.iter().any(|&f| f) {
                    return Err("shed while a pool fit the budget".into());
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_selector_prefers_quality_when_both_fit() {
    prop_check(300, 31, GenSelectorCase, |case| {
        let pred = LatencyPredictor::new(0.2);
        pred.record(EngineKind::AclStaged, 1, case.acl_ms);
        pred.record(EngineKind::Quant, 1, case.quant_ms);
        let pools = vec![
            PoolView {
                kind: EngineKind::AclStaged,
                queued: case.acl_queued,
                workers: 1,
                capacity: 8,
            },
            PoolView {
                kind: EngineKind::Quant,
                queued: case.quant_queued,
                workers: 1,
                capacity: 8,
            },
        ];
        let sel = Selector::new(case.margin, 1);
        let slo = Slo::with_deadline_ms(case.budget_ms);
        let acl_fits = pools[0].queued < pools[0].capacity
            && sel.predict_ms(&pred, &pools[0]) <= case.budget_ms;
        if let Decision::Route { pool, .. } =
            sel.choose(&pred, &pools, &slo, Some(case.budget_ms))
        {
            if acl_fits && pool != 0 {
                return Err("skipped the quality pool although it fit".into());
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Cache: bounded LRU whose hits are bit-identical to what was inserted.
// ---------------------------------------------------------------------------

/// Deterministic value for a key so bit-identity is checkable anywhere.
fn value_for(key: u64) -> CachedResult {
    CachedResult {
        top1: key as usize,
        top5: (0..5)
            .map(|i| (key as usize + i, (key as f32).sin() * 0.5 + i as f32))
            .collect(),
    }
}

fn bits_equal(a: &CachedResult, b: &CachedResult) -> bool {
    a.top1 == b.top1
        && a.top5.len() == b.top5.len()
        && a.top5
            .iter()
            .zip(&b.top5)
            .all(|((ci, cp), (di, dp))| ci == di && cp.to_bits() == dp.to_bits())
}

#[derive(Debug, Clone)]
struct CacheOps {
    capacity: usize,
    /// (key, is_put) over a small key space to force collisions/evictions.
    ops: Vec<(u64, bool)>,
}

struct GenCacheOps;

impl Gen for GenCacheOps {
    type Value = CacheOps;
    fn generate(&self, rng: &mut Rng) -> CacheOps {
        let capacity = rng.range(1, 6);
        let n = rng.range(0, 60);
        let ops = (0..n)
            .map(|_| (rng.below(10) as u64, rng.chance(0.5)))
            .collect();
        CacheOps { capacity, ops }
    }
    fn shrink(&self, v: &CacheOps) -> Vec<CacheOps> {
        let mut out = Vec::new();
        if v.ops.len() > 1 {
            out.push(CacheOps {
                capacity: v.capacity,
                ops: v.ops[..v.ops.len() / 2].to_vec(),
            });
            let mut one_less = v.ops.clone();
            one_less.pop();
            out.push(CacheOps {
                capacity: v.capacity,
                ops: one_less,
            });
        }
        out
    }
}

#[test]
fn prop_cache_hits_bit_identical_and_capacity_bounded() {
    prop_check(400, 37, GenCacheOps, |case| {
        let cache = ResponseCache::new(case.capacity);
        for &(key, is_put) in &case.ops {
            if is_put {
                cache.put(key, value_for(key));
            } else if let Some(hit) = cache.get(key) {
                // Values are keyed deterministically, so any hit must be
                // the exact bits that were inserted for this key.
                if !bits_equal(&hit, &value_for(key)) {
                    return Err(format!("hit for key {key} returned wrong bits"));
                }
            }
            if cache.len() > case.capacity {
                return Err(format!(
                    "len {} exceeds capacity {}",
                    cache.len(),
                    case.capacity
                ));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Worker loop shape: urgency sort + expiry shed + batch split disposes of
// every admitted request exactly once.
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
struct SloItem {
    id: usize,
    /// None = best-effort; Some(ms) = deadline from `submitted`.
    deadline_ms: Option<f64>,
    priority: Priority,
}

fn slo_of(item: &SloItem) -> Slo {
    let mut slo = match item.deadline_ms {
        Some(ms) => Slo::with_deadline_ms(ms),
        None => Slo::default(),
    };
    slo.priority = item.priority;
    slo
}

#[derive(Debug, Clone)]
struct SloLoad {
    max_batch: usize,
    items: Vec<SloItem>,
}

struct GenSloLoad;

impl Gen for GenSloLoad {
    type Value = SloLoad;
    fn generate(&self, rng: &mut Rng) -> SloLoad {
        let max_batch = rng.range(1, 8);
        let n = rng.range(0, 40);
        let items = (0..n)
            .map(|id| SloItem {
                id,
                // A third expired-on-arrival, a third tight, a third open.
                deadline_ms: match rng.below(3) {
                    0 => Some(1e-6), // effectively already expired
                    1 => Some(rng.uniform(50.0, 500.0)),
                    _ => None,
                },
                priority: match rng.below(3) {
                    0 => Priority::Hi,
                    1 => Priority::Normal,
                    _ => Priority::Lo,
                },
            })
            .collect();
        SloLoad { max_batch, items }
    }
    fn shrink(&self, v: &SloLoad) -> Vec<SloLoad> {
        let mut out = Vec::new();
        if v.items.len() > 1 {
            out.push(SloLoad {
                max_batch: v.max_batch,
                items: v.items[..v.items.len() / 2].to_vec(),
            });
        }
        out
    }
}

#[test]
fn prop_shed_and_serve_loop_never_drops_silently() {
    prop_check(300, 41, GenSloLoad, |case| {
        let policy = BatchPolicy::new(case.max_batch, Duration::ZERO, &[1, 2, 4, 8]);
        let q = BoundedQueue::new(64);
        let submitted = Instant::now();
        for item in &case.items {
            q.try_push(item.clone()).map_err(|_| "push failed".to_string())?;
        }
        // Mirror the worker loop: sort by urgency, form, partition expired
        // (each gets an explicit rejection), split, serve the batch.
        let mut served = Vec::new();
        let mut shed = Vec::new();
        while !q.is_empty() {
            q.sort_pending_by_key(|it| Urgency::of(&slo_of(it), submitted));
            let reqs = policy.form(&q).ok_or("no batch from non-empty queue")?;
            let now = Instant::now();
            let (expired, live): (Vec<SloItem>, Vec<SloItem>) = reqs
                .into_iter()
                .partition(|it| slo_of(it).expired(submitted, now));
            shed.extend(expired.into_iter().map(|it| it.id));
            if live.is_empty() {
                continue;
            }
            let (batch, leftover) = policy.split(live);
            if !leftover.is_empty() {
                q.push_front_bulk(leftover);
            }
            served.extend(batch.into_iter().map(|it| it.id));
        }
        let mut all: Vec<usize> = served.iter().chain(shed.iter()).copied().collect();
        all.sort_unstable();
        let expect: Vec<usize> = (0..case.items.len()).collect();
        if all != expect {
            return Err(format!(
                "disposition mismatch: served {served:?} shed {shed:?}"
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_urgency_sort_drains_in_priority_deadline_order() {
    prop_check(300, 43, GenSloLoad, |case| {
        let q = BoundedQueue::new(64);
        let submitted = Instant::now();
        for item in &case.items {
            q.try_push(item.clone()).map_err(|_| "push failed".to_string())?;
        }
        q.sort_pending_by_key(|it| Urgency::of(&slo_of(it), submitted));
        let mut last: Option<Urgency> = None;
        while let Some(it) = q.pop_wait(Duration::from_millis(1)) {
            let u = Urgency::of(&slo_of(&it), submitted);
            if let Some(prev) = last {
                if u < prev {
                    return Err(format!("urgency order violated at id {}", it.id));
                }
            }
            last = Some(u);
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// End-to-end versions against a real engine (skip without artifacts).
// ---------------------------------------------------------------------------

fn artifacts_ready() -> bool {
    zuluko::artifacts_dir().join("manifest.json").exists()
}

fn e2e_config() -> zuluko::config::Config {
    let mut cfg = zuluko::config::Config {
        engine: EngineKind::AclFused,
        workers: 1,
        max_batch: 4,
        batch_timeout: Duration::from_millis(10),
        queue_capacity: 32,
        ..zuluko::config::Config::default()
    };
    cfg.policy.cache_capacity = 32;
    cfg
}

#[test]
fn admitted_requests_always_answered_under_slo_mix() {
    if !artifacts_ready() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    use zuluko::coordinator::Coordinator;
    use zuluko::tensor::Tensor;

    let coord = Coordinator::start(&e2e_config()).unwrap();
    let mut rng = Rng::new(47);
    let mut receivers = Vec::new();
    let mut rejected = 0usize;
    for i in 0..24 {
        let slo = match rng.below(3) {
            0 => Slo::with_deadline_ms(rng.uniform(1.0, 20.0)), // likely shed
            1 => Slo::with_deadline_ms(60_000.0),               // always fits
            _ => Slo::default(),                                // best-effort
        };
        match coord.submit_with_slo(Tensor::random(&[227, 227, 3], i), slo) {
            Ok(rx) => receivers.push(rx),
            Err(_) => rejected += 1,
        }
    }
    // Every admitted request gets exactly one reply — ok, engine error,
    // or the structured deadline rejection — never a hang or a drop.
    let mut answered = 0usize;
    for rx in receivers {
        let resp = rx.recv_timeout(Duration::from_secs(120)).expect("reply");
        answered += 1;
        if let Some(err) = &resp.error {
            assert!(
                err.contains("deadline"),
                "unexpected error kind: {err}"
            );
        }
    }
    assert_eq!(answered + rejected, 24);
    coord.shutdown();
}

#[test]
fn cache_hit_bit_identical_to_cold_inference() {
    if !artifacts_ready() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    use zuluko::coordinator::Coordinator;
    use zuluko::tensor::Tensor;

    let coord = Coordinator::start(&e2e_config()).unwrap();
    let frame = || Tensor::random(&[227, 227, 3], 4242);

    let cold = coord.infer_blocking(frame()).unwrap();
    assert!(cold.is_ok(), "{:?}", cold.error);
    assert!(!cold.cached);

    let warm = coord.infer_blocking(frame()).unwrap();
    assert!(warm.is_ok(), "{:?}", warm.error);
    assert!(warm.cached, "second identical frame should hit the cache");
    assert_eq!(warm.engine, "cache");
    assert_eq!(warm.top1, cold.top1);
    assert_eq!(warm.top5.len(), cold.top5.len());
    for ((ci, cp), (wi, wp)) in cold.top5.iter().zip(&warm.top5) {
        assert_eq!(ci, wi);
        assert_eq!(cp.to_bits(), wp.to_bits(), "cache hit not bit-identical");
    }

    let stats = coord.stats();
    assert!(stats.cache_hits >= 1);
    coord.shutdown();
}
