//! Frame-lane property tests (ISSUE 9): the binary pixel frame
//! round-trip must be an identity, under arbitrary chunking of the wire
//! bytes, for random shapes — and the header-validation split
//! (`FrameHeader::check` vs `FrameHeader::resyncable`) must classify
//! every header into exactly one of {accept, recoverable reject,
//! connection-fatal reject}.
//!
//! Encode with the public client builder ([`InferRequest::frame`]),
//! deliver through the same [`Framing`] state machine the planes run,
//! parse the header with BOTH wire parsers — so this test pins the
//! client encoding, the framing layer, and parser parity in one loop.
//!
//! Case count is `FRAME_PROPS_CASES` (default 500); CI runs the same
//! test with a much larger count.

use zuluko::config::WireParser;
use zuluko::server::client::InferRequest;
use zuluko::server::conn::{Framing, WireItem};
use zuluko::server::protocol::{self, ClientMsg, FrameHeader, ImageSpec};
use zuluko::testkit::rng::Rng;
use zuluko::util::wire::WireTape;

fn cases(default: usize) -> usize {
    std::env::var("FRAME_PROPS_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

const MAX_LINE: usize = 64 * 1024;
const MAX_FRAME: usize = 8 * 1024 * 1024;

#[test]
fn frame_roundtrip_is_identity_under_arbitrary_chunking() {
    let n = cases(500);
    let mut r = Rng::new(0xF7A3E);
    let mut tape = WireTape::new();
    for i in 0..n {
        let h = 1 + r.below(24);
        let w = 1 + r.below(24);
        let pixels: Vec<u8> = (0..h * w * 3).map(|_| (r.next_u64() & 0xff) as u8).collect();

        // Client-side encoding.
        let req = InferRequest::new(i as u64).frame(h, w, 3, &pixels);
        let (line, payload) = req.request_line().unwrap();
        let payload = payload.expect("frame request carries a payload");
        assert_eq!(payload, &pixels[..], "builder must ship the pixels verbatim");

        // The exact bytes a socket would carry.
        let mut wire_bytes = line.into_bytes();
        wire_bytes.push(b'\n');
        wire_bytes.extend_from_slice(payload);

        // Server-side reassembly: feed in random-size chunks through
        // the planes' framing machine; parse the header with both
        // parsers; the reassembled payload must be byte-identical.
        let mut framing = Framing::new();
        let mut rbuf: Vec<u8> = Vec::new();
        let mut fed = 0usize;
        let mut start = 0usize;
        let mut header: Option<FrameHeader> = None;
        let reassembled: Vec<u8> = loop {
            match framing.next_item(&rbuf, start, MAX_LINE).unwrap() {
                Some(WireItem::Line(span)) => {
                    let line_bytes = &rbuf[span.clone()];
                    let (msg, key) =
                        protocol::parse_line(WireParser::Tape, line_bytes, &mut tape)
                            .expect("tape must accept the builder's encoding");
                    let (msg2, key2) =
                        protocol::parse_line(WireParser::Tree, line_bytes, &mut tape)
                            .expect("tree must accept the builder's encoding");
                    assert_eq!(msg, msg2, "parsers diverged on a frame header");
                    assert_eq!(key, key2);
                    assert_eq!(key, None, "frames are never wire-keyed");
                    match msg {
                        ClientMsg::Infer {
                            id,
                            image: ImageSpec::Frame(fh),
                            ..
                        } => {
                            assert_eq!(id, i as u64);
                            fh.check(MAX_FRAME).expect("valid header must check()");
                            assert_eq!(
                                (fh.len, fh.h, fh.w, fh.c, fh.dtype.as_str()),
                                (pixels.len(), h, w, 3, "u8")
                            );
                            framing.expect_payload(fh.len);
                            header = Some(fh);
                        }
                        other => panic!("expected a frame infer, got {other:?}"),
                    }
                    start = span.end + 1;
                }
                Some(WireItem::Frame(range)) => break rbuf[range].to_vec(),
                None => {
                    // Starvation guard: with every byte fed, the machine
                    // must have produced the frame already.
                    assert!(
                        fed < wire_bytes.len(),
                        "framing starved with all {} bytes fed (case {i})",
                        wire_bytes.len()
                    );
                    let step = (1 + r.below(97)).min(wire_bytes.len() - fed);
                    rbuf.extend_from_slice(&wire_bytes[fed..fed + step]);
                    fed += step;
                }
            }
        };
        assert!(header.is_some(), "payload surfaced before its header");
        assert_eq!(reassembled, pixels, "round-trip lost or reordered bytes");
    }
}

/// Every header lands in exactly one bucket, and the buckets agree
/// with the wire contract: accept ⇒ resyncable; reject with a
/// trustworthy len ⇒ recoverable (skip `len` bytes, keep serving);
/// len outside the budget ⇒ connection-fatal.
#[test]
fn header_check_and_resync_classify_every_header() {
    let n = cases(500) * 4;
    let mut r = Rng::new(0xBADF);
    let max = 4096;
    let lens = [0usize, 1, 2, 3, 12, 300, 4095, 4096, 4097, usize::MAX];
    let dims = [0usize, 1, 2, 4, 9, 1000, usize::MAX / 2];
    let dtypes = ["u8", "f32", "U8", ""];
    for _ in 0..n {
        let hdr = FrameHeader {
            len: lens[r.below(lens.len())],
            h: dims[r.below(dims.len())],
            w: dims[r.below(dims.len())],
            c: [3, 0, 1, 4][r.below(4)],
            dtype: dtypes[r.below(dtypes.len())].to_string(),
        };
        match hdr.check(max) {
            Ok(()) => {
                assert!(hdr.resyncable(max), "accepted header must be resyncable");
                assert_eq!(hdr.h * hdr.w * hdr.c, hdr.len);
                assert_eq!(hdr.dtype, "u8");
            }
            Err(msg) => {
                assert!(!msg.is_empty(), "reject must explain itself");
                let len_ok = hdr.len > 0 && hdr.len <= max;
                assert_eq!(
                    hdr.resyncable(max),
                    len_ok,
                    "resync must depend on len alone: {hdr:?}"
                );
                if !len_ok {
                    assert!(
                        msg.contains("max-frame-bytes"),
                        "fatal reject must name the bound: {msg}"
                    );
                }
            }
        }
    }
}

/// A truncated payload never surfaces: for any prefix of the wire
/// bytes that ends mid-payload, the framing machine reports "need more"
/// rather than a short frame.
#[test]
fn truncated_payload_never_surfaces() {
    let n = cases(200);
    let mut r = Rng::new(0x7C0FFEE);
    for i in 0..n {
        let h = 1 + r.below(8);
        let w = 1 + r.below(8);
        let pixels: Vec<u8> = (0..h * w * 3).map(|_| (r.next_u64() & 0xff) as u8).collect();
        let (line, payload) = InferRequest::new(i as u64)
            .frame(h, w, 3, &pixels)
            .request_line()
            .unwrap();
        let mut wire_bytes = line.into_bytes();
        wire_bytes.push(b'\n');
        let header_end = wire_bytes.len();
        wire_bytes.extend_from_slice(payload.unwrap());

        // Cut anywhere inside the payload (after the header line).
        let cut = header_end + r.below(pixels.len());
        let rbuf = &wire_bytes[..cut];
        let mut framing = Framing::new();
        let span = match framing.next_item(rbuf, 0, MAX_LINE).unwrap() {
            Some(WireItem::Line(span)) => span,
            other => panic!("expected the header line, got {other:?}"),
        };
        framing.expect_payload(pixels.len());
        assert!(
            framing
                .next_item(rbuf, span.end + 1, MAX_LINE)
                .unwrap()
                .is_none(),
            "short payload must not surface (cut {cut}/{})",
            wire_bytes.len()
        );
    }
}
