//! Registry invariants (DESIGN.md §8), end-to-end over the sim engine —
//! no artifacts or XLA needed, so these run everywhere including CI:
//!
//! * an unknown model is a structured reject, never a silent fallback
//!   to the default model;
//! * two models served concurrently in one process never cross replies
//!   (every reply carries its model's name and the sim oracle's top1);
//! * response-cache hits are per-model: the same bytes sent to two
//!   models make two cache entries with different answers;
//! * a hot reload under sustained load loses zero in-flight requests;
//! * concurrent reload + serve holds the invariants under the
//!   panic-safety harness (a panicking case is a failing case, not a
//!   poisoned test process).
//!
//! The sim engine's contract (engine::sim): top1 is a pure function of
//! (model name, pixels), so "reply crossed models" is directly
//! observable as a wrong class.

use std::panic::AssertUnwindSafe;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use zuluko::config::Config;
use zuluko::coordinator::{Coordinator, SubmitError};
use zuluko::engine::sim::expected_top1;
use zuluko::engine::EngineKind;
use zuluko::policy::Slo;
use zuluko::server::client::{Client, InferRequest};
use zuluko::server::Server;
use zuluko::tensor::image::Image;
use zuluko::tensor::Tensor;
use zuluko::testkit::prop::{prop_check, Gen};
use zuluko::testkit::rng::Rng;

const HW: usize = 227;
const CLASSES: usize = 1000;

/// A fresh synthetic-model artifacts dir.  Tags are unique per test so
/// concurrently running tests never touch each other's manifests.
fn model_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "zuluko_registry_props_{tag}_{}",
        std::process::id()
    ));
    zuluko::testkit::manifest::write_synthetic(&dir, tag, CLASSES, HW, &[1, 2, 4])
        .unwrap();
    dir
}

/// Two sim models, first one default.
fn two_model_cfg(a: &str, b: &str, cache: usize) -> Config {
    let mut cfg = Config {
        engine: EngineKind::Sim,
        workers: 1,
        max_batch: 4,
        batch_timeout: Duration::from_millis(5),
        queue_capacity: 32,
        ..Config::default()
    };
    cfg.policy.cache_capacity = cache;
    cfg.registry.upsert(a, model_dir(a));
    cfg.registry.upsert(b, model_dir(b));
    cfg.registry.default_model = Some(a.to_string());
    cfg.validate().unwrap();
    cfg
}

/// Exactly the pixels the server decodes for `{"synthetic": seed}`.
fn frame_pixels(seed: u64) -> Vec<f32> {
    let img = Image::synthetic(HW, HW, seed);
    let mut buf = vec![0.0f32; HW * HW * 3];
    img.to_input_into(&mut buf);
    buf
}

fn frame_tensor(seed: u64) -> Tensor {
    Tensor::new(&[HW, HW, 3], frame_pixels(seed)).unwrap()
}

/// Tear down server + coordinator like server_e2e does: wait for
/// connection handlers to release their Arc clones, then shutdown.
fn stop_all(server: Server, mut coord: Arc<Coordinator>) {
    server.stop();
    let coord = loop {
        match Arc::try_unwrap(coord) {
            Ok(c) => break c,
            Err(arc) => {
                coord = arc;
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    };
    coord.shutdown();
}

#[test]
fn unknown_model_rejected_not_defaulted() {
    let coord = Arc::new(Coordinator::start(&two_model_cfg("ua", "ub", 0)).unwrap());

    // Library surface: structured UnknownModel, not a default route.
    match coord.submit_model(Some("nope"), frame_tensor(1), Slo::default()) {
        Err(SubmitError::UnknownModel(m)) => assert_eq!(m, "nope"),
        Err(other) => panic!("expected UnknownModel, got {other:?}"),
        Ok(_) => panic!("unknown model was silently served"),
    }

    // Wire surface: structured `unknown_model` kind, connection stays up.
    let server = Server::start(coord.clone(), "127.0.0.1:0").unwrap();
    let mut c = Client::connect(&server.addr().to_string()).unwrap();
    let r = c.infer(&InferRequest::new(1).synthetic(42).model("nope")).unwrap();
    assert!(!r.ok);
    assert_eq!(r.kind.as_deref(), Some("unknown_model"));

    // Absent model field = default model, by name.
    let r = c.infer(&InferRequest::new(2).synthetic(42)).unwrap();
    assert!(r.ok, "default-model request failed: {:?}", r.error);
    assert_eq!(r.model, "ua");
    assert_eq!(r.top1, expected_top1("ua", &frame_pixels(42), CLASSES));

    drop(c);
    stop_all(server, coord);
}

/// Acceptance e2e: two models in one process, hammered concurrently
/// with the *same* seeds, must never cross replies — and their caches
/// must be disjoint (same bytes -> two entries, two answers).
#[test]
fn two_models_serve_concurrently_without_crossing() {
    let coord = Arc::new(Coordinator::start(&two_model_cfg("xa", "xb", 64)).unwrap());
    let server = Server::start(coord.clone(), "127.0.0.1:0").unwrap();
    let addr = server.addr().to_string();

    const SEEDS: u64 = 12;
    const THREADS_PER_MODEL: usize = 2;
    let mut handles = Vec::new();
    for model in ["xa", "xb"] {
        for t in 0..THREADS_PER_MODEL {
            let addr = addr.clone();
            let model = model.to_string();
            handles.push(std::thread::spawn(move || {
                let mut c = Client::connect(&addr).unwrap();
                for i in 0..SEEDS {
                    let seed = 5000 + i; // same seeds for both models
                    let id = t as u64 * 10_000 + i;
                    let r = c.infer(&InferRequest::new(id).synthetic(seed).model(&model)).unwrap();
                    assert!(r.ok, "{model} seed {seed}: {:?}", r.error);
                    assert_eq!(r.id, id);
                    assert_eq!(r.model, model, "reply crossed models");
                    let want = expected_top1(&model, &frame_pixels(seed), CLASSES);
                    assert_eq!(
                        r.top1, want,
                        "{model} seed {seed}: wrong class — a reply or \
                         cache entry crossed models"
                    );
                }
            }));
        }
    }
    for h in handles {
        h.join().unwrap();
    }

    // Same bytes, two models -> two live cache entries, two answers.
    let mut c = Client::connect(&addr).unwrap();
    let ra = c.infer(&InferRequest::new(900).synthetic(5000).model("xa")).unwrap();
    let rb = c.infer(&InferRequest::new(901).synthetic(5000).model("xb")).unwrap();
    assert!(ra.cached, "repeat frame should hit xa's cache");
    assert!(rb.cached, "repeat frame should hit xb's cache");
    assert_eq!(ra.top1, expected_top1("xa", &frame_pixels(5000), CLASSES));
    assert_eq!(rb.top1, expected_top1("xb", &frame_pixels(5000), CLASSES));

    let policy = c.policy().unwrap();
    let models = policy.get("models").and_then(|m| m.as_arr()).unwrap();
    assert_eq!(models.len(), 2);
    for m in models {
        let name = m.str_of("model").unwrap();
        let len = m.get("cache").unwrap().usize_of("len").unwrap();
        assert!(len >= 1, "model {name} cache is empty — entries collapsed");
    }

    drop(c);
    stop_all(server, coord);
}

#[test]
fn per_model_cache_isolation_same_bytes_two_entries() {
    let coord = Coordinator::start(&two_model_cfg("ca", "cb", 64)).unwrap();
    let want_a = expected_top1("ca", &frame_pixels(7), CLASSES);
    let want_b = expected_top1("cb", &frame_pixels(7), CLASSES);

    let submit = |model: &str| {
        coord
            .submit_model(Some(model), frame_tensor(7), Slo::default())
            .unwrap()
            .recv()
            .unwrap()
    };

    let ra = submit("ca");
    let rb = submit("cb");
    assert!(!ra.cached && !rb.cached, "cold path must run inference");
    assert_eq!(ra.top1, want_a);
    assert_eq!(rb.top1, want_b);

    // Warm path: each model hits its own entry with its own answer.
    let ra2 = submit("ca");
    let rb2 = submit("cb");
    assert!(ra2.cached && rb2.cached, "repeat frames must hit the cache");
    assert_eq!(ra2.top1, want_a, "ca cache entry crossed models");
    assert_eq!(rb2.top1, want_b, "cb cache entry crossed models");

    let snap = coord.policy_snapshot();
    assert_eq!(snap.models.len(), 2);
    for m in &snap.models {
        assert!(m.loaded);
        assert!(
            m.cache.len >= 1,
            "model {} holds no cache entry — same-bytes requests collapsed \
             into one cross-model entry",
            m.model
        );
        assert!(m.cache.hits >= 1, "model {} never hit", m.model);
    }

    coord.shutdown();
}

/// Acceptance e2e: hot reload under sustained two-model load.  Every
/// request sent gets a correct, same-model reply; reloads bump the
/// generation; nothing is dropped or crossed while generations swap.
#[test]
fn hot_reload_under_load_loses_no_inflight_requests() {
    let mut cfg = two_model_cfg("ra", "rb", 0);
    // Preload both models so the generation arithmetic below is
    // deterministic (lazy first-touch could otherwise race the reloads).
    cfg.registry.preload = true;
    let coord = Arc::new(Coordinator::start(&cfg).unwrap());
    let server = Server::start(coord.clone(), "127.0.0.1:0").unwrap();
    let addr = server.addr().to_string();

    let stop = Arc::new(AtomicBool::new(false));
    let mut handles = Vec::new();
    for model in ["ra", "rb"] {
        for t in 0..2u64 {
            let addr = addr.clone();
            let model = model.to_string();
            let stop = stop.clone();
            handles.push(std::thread::spawn(move || -> u64 {
                let mut c = Client::connect(&addr).unwrap();
                let mut sent = 0u64;
                let mut i = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    // Distinct seeds: cache is off, every request must
                    // reach an engine (real in-flight work).
                    let seed = (t << 32) | i;
                    let r = c.infer(&InferRequest::new(i).synthetic(seed).model(&model)).unwrap();
                    assert!(
                        r.ok,
                        "{model} lost a request during reload: {:?} ({:?})",
                        r.error, r.kind
                    );
                    assert_eq!(r.model, model, "reply crossed models");
                    assert_eq!(
                        r.top1,
                        expected_top1(&model, &frame_pixels(seed), CLASSES),
                        "{model}: wrong class during reload"
                    );
                    sent += 1;
                    i += 1;
                }
                sent
            }));
        }
    }

    // Reload both models repeatedly while the load runs.
    let mut admin = Client::connect(&addr).unwrap();
    std::thread::sleep(Duration::from_millis(50));
    for round in 0..3 {
        for model in ["ra", "rb"] {
            let j = admin.reload(Some(model)).unwrap();
            assert_eq!(
                j.get("ok").and_then(|v| v.as_bool()),
                Some(true),
                "reload {model} round {round} failed: {j:?}"
            );
        }
        std::thread::sleep(Duration::from_millis(40));
    }
    stop.store(true, Ordering::Relaxed);
    let sent: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert!(sent > 0, "load generator sent nothing — test proved nothing");

    // Generations moved: initial load = gen 1, plus 3 reloads each.
    let stats = admin.stats().unwrap();
    let models = stats.get("models").and_then(|m| m.as_arr()).unwrap();
    for m in models {
        assert_eq!(m.usize_of("generation").unwrap(), 4, "{m:?}");
        assert_eq!(m.usize_of("rejected").unwrap(), 0, "requests rejected");
    }

    drop(admin);
    stop_all(server, coord);
}

#[test]
fn reload_failure_keeps_old_generation_serving() {
    let cfg = two_model_cfg("fa", "fb", 0);
    let dir_b = model_dir("fb"); // same path the registry uses
    let coord = Coordinator::start(&cfg).unwrap();

    // Load fb, then corrupt its manifest on disk.
    let r = coord
        .submit_model(Some("fb"), frame_tensor(3), Slo::default())
        .unwrap()
        .recv()
        .unwrap();
    assert!(r.is_ok());
    std::fs::write(dir_b.join("manifest.json"), "not json").unwrap();

    // Reload fails fast...
    assert!(coord.reload(Some("fb")).is_err());
    // ...and the old generation keeps serving, untouched.
    let r = coord
        .submit_model(Some("fb"), frame_tensor(4), Slo::default())
        .unwrap()
        .recv()
        .unwrap();
    assert!(r.is_ok(), "old generation died with the failed reload");
    assert_eq!(r.top1, expected_top1("fb", &frame_pixels(4), CLASSES));

    // Fixed artifacts reload cleanly.
    zuluko::testkit::manifest::write_synthetic(&dir_b, "fb", CLASSES, HW, &[1, 2, 4])
        .unwrap();
    let report = coord.reload(Some("fb")).unwrap();
    assert!(report.generation >= 3, "failed attempt must not stall numbering");

    coord.shutdown();
}

// ---------------------------------------------------------------------------
// Property: concurrent reload + serve, under the panic-safety harness.
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
struct ReloadCase {
    requests: usize,
    reload_every: usize,
    seed: u64,
}

struct GenReloadCase;

impl Gen for GenReloadCase {
    type Value = ReloadCase;
    fn generate(&self, rng: &mut Rng) -> ReloadCase {
        ReloadCase {
            requests: rng.range(4, 16),
            reload_every: rng.range(1, 6),
            seed: rng.next_u64(),
        }
    }
    fn shrink(&self, v: &ReloadCase) -> Vec<ReloadCase> {
        let mut out = Vec::new();
        if v.requests > 4 {
            out.push(ReloadCase {
                requests: v.requests / 2,
                ..v.clone()
            });
        }
        if v.reload_every > 1 {
            out.push(ReloadCase {
                reload_every: 1,
                ..v.clone()
            });
        }
        out
    }
}

#[test]
fn prop_concurrent_reload_and_serve_never_drops_or_crosses() {
    // One coordinator shared across cases would hide per-case state;
    // each case builds its own (sim engines make this cheap).
    prop_check(6, 41, GenReloadCase, |case| {
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let coord =
                Coordinator::start(&two_model_cfg("pa", "pb", 8)).unwrap();
            let models = ["pa", "pb"];
            for i in 0..case.requests {
                let model = models[i % 2];
                if i % case.reload_every == 0 {
                    coord.reload(Some(model)).map_err(|e| format!("reload: {e}"))?;
                }
                let seed = case.seed ^ (i as u64);
                // A reload can retire the resolved generation between
                // resolve and route; Closed is the documented transient
                // — re-resolving must succeed.
                let mut rx = None;
                for _ in 0..3 {
                    match coord.submit_model(
                        Some(model),
                        frame_tensor(seed),
                        Slo::default(),
                    ) {
                        Ok(r) => {
                            rx = Some(r);
                            break;
                        }
                        Err(SubmitError::Closed) => continue,
                        Err(e) => return Err(format!("submit: {e}")),
                    }
                }
                let rx = rx.ok_or("submit kept hitting Closed")?;
                // Every admitted request must get exactly one reply.
                let resp = rx
                    .recv()
                    .map_err(|_| "admitted request dropped".to_string())?;
                if !resp.is_ok() {
                    return Err(format!("request failed: {:?}", resp.error));
                }
                if &*resp.model != model {
                    return Err(format!(
                        "reply crossed models: wanted {model}, got {}",
                        resp.model
                    ));
                }
                let want = expected_top1(model, &frame_pixels(seed), CLASSES);
                if resp.top1 != want {
                    return Err(format!(
                        "{model}: top1 {} != expected {want}",
                        resp.top1
                    ));
                }
            }
            coord.shutdown();
            Ok(())
        }));
        match result {
            Ok(inner) => inner,
            Err(_) => Err("panicked during concurrent reload + serve".into()),
        }
    });
}
