//! End-to-end observability (DESIGN.md §10): the tracing plane over a
//! live server — `{"cmd":"metrics"}` merges every subsystem into one
//! line, `{"cmd":"trace"}` returns retained timelines, and a request
//! that misses its deadline is always captured in the slow log with all
//! eight stage marks in monotonic order.
//!
//! The deadline miss is staged deterministically: worker replicas build
//! lazily on first serve and `SimEngine::new` reads ZULUKO_SIM_EXEC_US
//! at that moment, so setting the env var after server start but before
//! the first request gives an engine whose real cost (500ms/image)
//! dwarfs the admission predictor's cold prior (1ms/image) — the
//! request is admitted against a 200ms budget, executes, and misses.
//! The env var is process-global, so every test here serializes on one
//! lock and cleans up before releasing it.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use zuluko::config::Config;
use zuluko::coordinator::Coordinator;
use zuluko::engine::sim::SIM_EXEC_ENV;
use zuluko::engine::EngineKind;
use zuluko::obs::STAGE_NAMES;
use zuluko::server::client::{Client, InferRequest};
use zuluko::server::Server;
use zuluko::util::json::Json;

const HW: usize = 64;
const MODEL: &str = "m";

/// Serializes the env-var window across tests in this binary.
static ENV_LOCK: Mutex<()> = Mutex::new(());

fn start(tag: &str, sample_rate: f64) -> (Server, Arc<Coordinator>) {
    let dir = std::env::temp_dir().join(format!("zuluko_obs_e2e_{tag}_{}", std::process::id()));
    zuluko::testkit::manifest::write_synthetic(&dir, MODEL, 100, HW, &[1, 2, 4]).unwrap();
    let mut cfg = Config {
        engine: EngineKind::Sim,
        workers: 1,
        max_batch: 4,
        batch_timeout: Duration::from_millis(5),
        queue_capacity: 64,
        ..Config::default()
    };
    cfg.registry.upsert(MODEL, dir);
    cfg.registry.default_model = Some(MODEL.to_string());
    cfg.obs.trace_sample_rate = sample_rate;
    cfg.validate().unwrap();
    let coord = Arc::new(Coordinator::start(&cfg).unwrap());
    let s = Server::start_with(coord.clone(), "127.0.0.1:0", &cfg.server).unwrap();
    (s, coord)
}

fn stop_all(server: Server, mut coord: Arc<Coordinator>) {
    server.stop();
    let coord = loop {
        match Arc::try_unwrap(coord) {
            Ok(c) => break c,
            Err(arc) => {
                coord = arc;
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    };
    coord.shutdown();
}

/// Marks present in a serialized span, as (stage index, ms offset),
/// in stage order.
fn present_marks(span: &Json) -> Vec<(usize, f64)> {
    let marks = span.get("marks").expect("span has marks");
    STAGE_NAMES
        .iter()
        .enumerate()
        .filter_map(|(i, name)| marks.f64_of(name).ok().map(|v| (i, v)))
        .collect()
}

fn assert_marks_monotonic(span: &Json) {
    let pm = present_marks(span);
    for w in pm.windows(2) {
        assert!(
            w[1].1 >= w[0].1,
            "marks out of order: stage {} at {}ms after stage {} at {}ms ({span:?})",
            w[1].0,
            w[1].1,
            w[0].0,
            w[0].1
        );
    }
}

#[test]
fn metrics_merges_every_subsystem_and_traces_round_trip() {
    let _g = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let (server, coord) = start("metrics", 1.0);
    let addr = server.addr().to_string();
    let mut c = Client::connect(&addr).unwrap();

    // Distinct seeds: every request is a real inference (no wire-key
    // cache hits), so full 8-stage timelines exist.
    const N: u64 = 12;
    for i in 0..N {
        let r = c.infer(&InferRequest::new(i).synthetic(500 + i)).unwrap();
        assert!(r.ok, "{:?}", r.error);
    }

    // --- {"cmd":"metrics"}: one line, every subsystem present. ---
    let m = c.metrics().unwrap();
    assert_eq!(m.get("ok").and_then(|v| v.as_bool()), Some(true));
    assert!(m.usize_of("completed").unwrap() >= N as usize);
    for section in ["latency", "pool", "conn", "proc", "trace"] {
        assert!(m.get(section).is_some(), "metrics missing {section}");
    }

    // Per-stage histogram rows: real durations, sane quantiles.
    let stages = m.get("stages").and_then(|v| v.as_arr()).expect("stages");
    assert!(!stages.is_empty(), "no stage rows after {N} requests");
    for row in stages {
        let name = row.str_of("stage").expect("row has stage name");
        assert!(STAGE_NAMES.contains(&name), "unknown stage {name}");
        assert!(row.usize_of("count").unwrap() >= 1);
        let p50 = row.f64_of("p50_ms").unwrap();
        let p99 = row.f64_of("p99_ms").unwrap();
        let max = row.f64_of("max_ms").unwrap();
        assert!(p50 >= 0.0 && p99 >= p50 && max >= p99, "{name}: {p50}/{p99}/{max}");
    }
    // The inference segment itself must have been measured.
    assert!(
        stages.iter().any(|r| r.str_of("stage").ok() == Some("infer_done")),
        "no infer_done row in {stages:?}"
    );
    let ms = m.get("model_stages").and_then(|v| v.as_arr()).unwrap();
    assert!(ms.iter().any(|r| r.str_of("model").ok() == Some(MODEL)));

    // Trace counters: rate 1.0 records every completion.
    let t = m.get("trace").unwrap();
    assert_eq!(t.usize_of("sample_period").ok(), Some(1));
    assert!(t.usize_of("begun").unwrap() >= N as usize);
    assert!(t.usize_of("completed").unwrap() >= N as usize);
    assert!(t.usize_of("recorded").unwrap() >= N as usize);
    assert_eq!(t.usize_of("sampled_out").ok(), Some(0));

    // --- {"cmd":"trace"}: retained timelines, monotonic, complete. ---
    let tr = c.trace(64).unwrap();
    assert_eq!(tr.get("ok").and_then(|v| v.as_bool()), Some(true));
    let traces = tr.get("traces").and_then(|v| v.as_arr()).expect("traces");
    assert!(traces.len() >= N as usize, "retained {} of {N}", traces.len());
    for span in traces {
        assert_marks_monotonic(span);
        let flags = span.get("flags").and_then(|v| v.as_arr()).unwrap();
        assert!(
            flags.iter().any(|f| f.as_str() == Some("sampled")),
            "retained span not marked sampled: {span:?}"
        );
    }
    // At least one full 8-stage timeline among them.
    assert!(
        traces.iter().any(|s| present_marks(s).len() == STAGE_NAMES.len()),
        "no complete 8-stage timeline retained"
    );

    // The n clamp: asking for 1 returns at most 1.
    let one = c.trace(1).unwrap();
    assert!(one.get("traces").and_then(|v| v.as_arr()).unwrap().len() <= 1);

    drop(c);
    stop_all(server, coord);
}

#[test]
fn deadline_missed_request_lands_in_slow_log_with_full_timeline() {
    let _g = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    // Sample rate 0: the slow log must capture the anomaly even with
    // per-request tracing sampled out entirely.
    let (server, coord) = start("miss", 0.0);
    let addr = server.addr().to_string();
    let mut c = Client::connect(&addr).unwrap();

    // Inflate the sim engine *after* start, *before* the first request:
    // the worker's replica builds lazily on first serve and reads this.
    std::env::set_var(SIM_EXEC_ENV, "500000"); // 500ms/image
    let r = c.infer(&InferRequest::new(1).synthetic(42).deadline_ms(200.0)).unwrap();
    std::env::remove_var(SIM_EXEC_ENV);
    assert!(r.ok, "admitted request must still answer: {:?}", r.error);
    assert!(
        r.total_ms > 200.0,
        "engine not inflated (total {}ms) — miss not staged",
        r.total_ms
    );

    let tr = c.trace(32).unwrap();
    let slow = tr.get("slow").and_then(|v| v.as_arr()).expect("slow log");
    let miss = slow
        .iter()
        .find(|s| {
            s.get("flags")
                .and_then(|v| v.as_arr())
                .is_some_and(|f| f.iter().any(|x| x.as_str() == Some("deadline_missed")))
        })
        .unwrap_or_else(|| panic!("no deadline_missed span in slow log: {slow:?}"));

    // All eight stages stamped, in order, and the total really blew
    // through the 200ms budget recorded on the span.
    assert_eq!(
        present_marks(miss).len(),
        STAGE_NAMES.len(),
        "missed span lacks stage marks: {miss:?}"
    );
    assert_marks_monotonic(miss);
    assert_eq!(miss.f64_of("deadline_ms").ok(), Some(200.0));
    assert!(miss.f64_of("total_ms").unwrap() > 200.0);

    // Sampled out (rate 0): the anomaly is in the slow log only — the
    // trace rings hold zero residue.
    assert!(
        tr.get("traces").and_then(|v| v.as_arr()).unwrap().is_empty(),
        "rate 0 must retain nothing in the rings"
    );
    let m = c.metrics().unwrap();
    let t = m.get("trace").unwrap();
    assert!(t.usize_of("anomalies").unwrap() >= 1);
    assert_eq!(t.usize_of("recorded").ok(), Some(0));

    drop(c);
    stop_all(server, coord);
}
