//! Stub of the `xla` PJRT bindings (API-compatible with the surface
//! `zuluko::runtime` uses).
//!
//! The real crate links the PJRT C API and the CPU plugin, which are not
//! available in every build environment.  This stub keeps the crate
//! compiling and the non-engine test suite green: literal construction
//! and inspection work in-memory, while anything that would launch real
//! XLA work ([`PjRtClient::cpu`], [`HloModuleProto::from_text_file`])
//! returns a descriptive error.  Engine-dependent tests and benches
//! already gate on `artifacts/manifest.json` and skip cleanly.
//!
//! To run real inference, swap this for the real bindings in
//! `rust/Cargo.toml` via a `[patch]` section; no zuluko source changes
//! are needed.

use std::fmt;
use std::path::Path;

/// Error type mirroring the real crate's (implements `std::error::Error`
/// so `anyhow::Context` applies).
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    fn unavailable(what: &str) -> Error {
        Error {
            msg: format!(
                "{what} is unavailable: zuluko was built against the stub \
                 `xla` crate (no PJRT plugin); see rust/vendor/xla"
            ),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types the manifest pipeline emits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S8,
}

impl ElementType {
    fn byte_size(self) -> usize {
        match self {
            ElementType::F32 => 4,
            ElementType::S8 => 1,
        }
    }
}

/// Conversion target for [`Literal::to_vec`].
pub trait NativeType: Sized {
    const BYTES: usize;
    fn from_le_bytes(b: &[u8]) -> Self;
}

impl NativeType for f32 {
    const BYTES: usize = 4;
    fn from_le_bytes(b: &[u8]) -> f32 {
        f32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }
}

impl NativeType for i8 {
    const BYTES: usize = 1;
    fn from_le_bytes(b: &[u8]) -> i8 {
        b[0] as i8
    }
}

/// Host-side array value: element type + dims + raw little-endian bytes.
#[derive(Debug, Clone)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<i64>,
    bytes: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let n: usize = dims.iter().product();
        if n * ty.byte_size() != data.len() {
            return Err(Error {
                msg: format!(
                    "literal shape {:?} ({ty:?}) wants {} bytes, got {}",
                    dims,
                    n * ty.byte_size(),
                    data.len()
                ),
            });
        }
        Ok(Literal {
            ty,
            dims: dims.iter().map(|&d| d as i64).collect(),
            bytes: data.to_vec(),
        })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape {
            dims: self.dims.clone(),
        })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.bytes.len() % T::BYTES != 0 {
            return Err(Error {
                msg: format!(
                    "literal byte length {} not divisible by element size {}",
                    self.bytes.len(),
                    T::BYTES
                ),
            });
        }
        Ok(self
            .bytes
            .chunks_exact(T::BYTES)
            .map(T::from_le_bytes)
            .collect())
    }

    /// Unwrap a 1-tuple result (artifacts are lowered with
    /// `return_tuple=True`).  The stub's literals are never tuples, so
    /// this is the identity.
    pub fn to_tuple1(self) -> Result<Literal> {
        Ok(self)
    }
}

/// Array shape view (`dims()` in the real crate returns i64 dims).
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module (never constructible in the stub).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        let _ = path.as_ref();
        Err(Error::unavailable("HLO parsing"))
    }
}

/// Computation wrapper.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client (never constructible in the stub — `cpu()` errors, so the
/// executable/buffer methods below are unreachable but keep real
/// signatures for drop-in compatibility).
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PJRT CPU client"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("compilation"))
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("execution"))
    }
}

/// Device buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("device-to-host transfer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let vals = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes)
                .unwrap();
        assert_eq!(lit.array_shape().unwrap().dims(), &[3i64]);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vals);
    }

    #[test]
    fn literal_rejects_size_mismatch() {
        assert!(Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[2],
            &[0u8; 4]
        )
        .is_err());
    }

    #[test]
    fn client_is_gated() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("stub"));
    }
}
