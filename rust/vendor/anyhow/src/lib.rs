//! Vendored minimal reimplementation of the `anyhow` API surface this
//! repository uses (DESIGN.md §Substitutions: builds must not touch a
//! network, so the error crate is in-tree).
//!
//! Implemented: [`Error`], [`Result`], the [`Context`] extension trait
//! for `Result`/`Option`, and the `anyhow!` / `bail!` / `ensure!`
//! macros.  `Display` prints the outermost message; the alternate form
//! (`{:#}`) prints the whole cause chain separated by `": "`, matching
//! upstream anyhow closely enough for log lines and test assertions.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` — the crate-wide fallible return type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

enum Repr {
    Msg(String),
    Boxed(Box<dyn StdError + Send + Sync + 'static>),
    Context { msg: String, source: Box<Error> },
}

/// A dynamic error with an optional chain of context layers.
///
/// Deliberately does **not** implement `std::error::Error`, mirroring
/// upstream anyhow: that is what makes the blanket
/// `From<E: std::error::Error>` impl coherent.
pub struct Error {
    repr: Repr,
}

impl Error {
    /// Wrap a concrete error type.
    pub fn new<E>(error: E) -> Error
    where
        E: StdError + Send + Sync + 'static,
    {
        Error {
            repr: Repr::Boxed(Box::new(error)),
        }
    }

    /// Build an error from any displayable message (used as
    /// `map_err(anyhow::Error::msg)` for `String` errors).
    pub fn msg<M>(message: M) -> Error
    where
        M: fmt::Display + fmt::Debug + Send + Sync + 'static,
    {
        Error {
            repr: Repr::Msg(message.to_string()),
        }
    }

    /// Wrap this error in a new outermost context layer.
    pub fn context<C>(self, context: C) -> Error
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        Error {
            repr: Repr::Context {
                msg: context.to_string(),
                source: Box::new(self),
            },
        }
    }

    /// The cause chain, outermost message first.
    fn chain_strings(&self) -> Vec<String> {
        let mut out = Vec::new();
        let mut cur = self;
        loop {
            match &cur.repr {
                Repr::Msg(m) => {
                    out.push(m.clone());
                    return out;
                }
                Repr::Boxed(e) => {
                    out.push(e.to_string());
                    let mut src = e.source();
                    while let Some(s) = src {
                        out.push(s.to_string());
                        src = s.source();
                    }
                    return out;
                }
                Repr::Context { msg, source } => {
                    out.push(msg.clone());
                    cur = source;
                }
            }
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let chain = self.chain_strings();
        if f.alternate() {
            write!(f, "{}", chain.join(": "))
        } else {
            write!(f, "{}", chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let chain = self.chain_strings();
        write!(f, "{}", chain[0])?;
        if chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: StdError + Send + Sync + 'static,
{
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: StdError + Send + Sync + 'static,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T, Error> for Result<T, Error> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context.to_string()))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string (or a displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// `return Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// `bail!` unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_outermost_only() {
        let e: Error = Error::new(io_err()).context("reading manifest");
        assert_eq!(format!("{e}"), "reading manifest");
    }

    #[test]
    fn alternate_prints_chain() {
        let e = Error::new(io_err())
            .context("reading manifest")
            .context("loading config");
        assert_eq!(format!("{e:#}"), "loading config: reading manifest: gone");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn context_on_option_and_result() {
        let none: Option<u32> = None;
        let e = none.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");

        let r: std::result::Result<u32, std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 3: gone");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(7).unwrap_err()), "unlucky 7");
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
    }
}
