//! E4 / Figure 4: vector quantization — conv-level win vs graph-level loss.
//!
//! Paper: int8 makes conv ~25% faster (NEON 8-bit SIMD) but the inserted
//! re-quantize/de-quantize ops cost more than the win; end-to-end slows
//! by >100 ms.  We measure the fp32 and quantized baseline graphs and
//! report both the measured conv ratio (XLA-CPU int8 gains little — see
//! DESIGN.md §Substitutions) and the overhead-vs-win accounting under the
//! paper's own 1.25x conv speedup.
//! Run: cargo bench --bench fig4_quant [-- --iters N | --quick]

use zuluko::bench::{Bench, BenchArgs};
use zuluko::engine::{build, Engine, EngineKind};
use zuluko::metrics::ledger::Group;
use zuluko::runtime::Manifest;
use zuluko::tensor::Tensor;

fn conv_ms(e: &dyn Engine, n: f64) -> f64 {
    e.ledger()
        .rows()
        .iter()
        .filter(|(name, g, _, _)| {
            *g == Group::Group1
                && (name == "conv1"
                    || name == "conv10"
                    || name.ends_with("_squeeze")
                    || name.ends_with("_expand1")
                    || name.ends_with("_expand3")
                    || name.ends_with("_q8"))
        })
        .map(|(_, _, _, ms)| ms)
        .sum::<f64>()
        / n
}

fn main() {
    let args = BenchArgs::from_env(8);
    let dir = zuluko::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP fig4_quant: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(&dir).expect("manifest");
    let input = Tensor::random(&[1, 227, 227, 3], 9);
    let n = (args.iters + args.warmup) as f64;

    println!("== E4 / Fig 4: quantization (iters={}) ==", args.iters);

    let mut tf = build(EngineKind::TfBaseline, &manifest).expect("tf");
    tf.warmup().expect("warmup");
    tf.ledger_mut().clear();
    let tf_e2e = Bench::new("fp32")
        .warmup(args.warmup)
        .iters(args.iters)
        .run(|| {
            tf.infer(&input).expect("infer");
        });
    let tf_conv = conv_ms(tf.as_ref(), n);

    let mut q = build(EngineKind::Quant, &manifest).expect("quant");
    q.warmup().expect("warmup");
    q.ledger_mut().clear();
    let q_e2e = Bench::new("quant")
        .warmup(args.warmup)
        .iters(args.iters)
        .run(|| {
            q.infer(&input).expect("infer");
        });
    let q_conv = conv_ms(q.as_ref(), n);
    let q_overhead = q.ledger().group_ms()[2] / n;

    println!("| quantity | fp32 | quant | delta | paper |");
    println!("|---|---|---|---|---|");
    println!(
        "| conv ops ms/img | {:.1} | {:.1} | {:+.0}% | -25% |",
        tf_conv,
        q_conv,
        (q_conv / tf_conv - 1.0) * 100.0
    );
    println!("| q/dq overhead ms/img | 0 | {q_overhead:.1} | +{q_overhead:.1} | 'significant' |");
    println!(
        "| end-to-end ms/img | {:.1} | {:.1} | {:+.1} | >+100 ms |",
        tf_e2e.mean_ms,
        q_e2e.mean_ms,
        q_e2e.mean_ms - tf_e2e.mean_ms
    );

    // Crossover accounting under the paper's own NEON conv win (1.25x):
    let paper_win = tf_conv * 0.20; // 25% faster = pays back 20% of fp32 time
    println!(
        "\ncrossover (paper-scaled): conv win {paper_win:.1} ms vs overhead {q_overhead:.1} ms -> {}",
        if q_overhead > paper_win {
            "quantization LOSES end-to-end (matches Fig 4)"
        } else {
            "quantization wins (contradicts Fig 4 on this substrate)"
        }
    );
}
