//! E14: request-lifecycle tracing overhead (DESIGN.md §10).
//!
//! The tracing plane promises "compiled in, effectively free": eight
//! monotonic stage stamps plus one hub completion per request, with the
//! ring push behind head sampling.  This bench drives a deterministic
//! stand-in for the serving hot path — synthetic decode into a reused
//! buffer, content-key hash, a small owned reply allocation, exactly the
//! per-request shape of the worker loop — through the *full* tracing
//! call sequence (begin → 8 stamps → complete), under three hubs:
//!
//! * `sampled_out` — `--trace-sample-rate 0`: tracing compiled in, every
//!   request sampled out.  The baseline the gate compares against.
//! * `default`     — the shipped 1-in-100 head sampling.
//! * `always`      — rate 1.0, every request pushed to a ring
//!   (informational: the worst-case cost, not gated).
//!
//! Modes are interleaved in alternating chunks so machine-load drift on
//! a shared CI runner lands on all of them equally.  Acceptance gate
//! (ISSUE 7): `default` vs `sampled_out` must stay within **5% p99**
//! and **5% allocation events per request** — tracing never allocates
//! on the hot path, so the alloc delta should be exactly zero.
//!
//! Run: cargo bench --bench trace_overhead [-- --quick] [--json PATH]

use std::time::Instant;

use zuluko::bench::BenchArgs;
use zuluko::metrics::Histogram;
use zuluko::obs::{ObsHub, Stage};
use zuluko::policy::image_key;
use zuluko::testkit::alloc::CountingAlloc;
use zuluko::testkit::rng::Rng;
use zuluko::util::json::Json;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

const HW: usize = 64;
const PER: usize = HW * HW * 3;
const CHUNK: usize = 256;
const RINGS: usize = 4;

/// The per-request serving work the stamps wrap: synthetic decode into
/// a reused buffer, content hash, and one small owned reply vec (the
/// worker's top-5 analogue) — so allocs/request has a real denominator.
fn request_work(buf: &mut [f32], rng: &mut Rng, sink: &mut u64) {
    for v in buf.iter_mut() {
        *v = rng.uniform(-1.0, 1.0) as f32;
    }
    let key = image_key(buf);
    let top: Vec<u64> = (0..5).map(|i| key.rotate_left(i)).collect();
    *sink = sink.wrapping_add(top.iter().copied().fold(0, u64::wrapping_add));
}

/// One fully-traced request: the exact stamp sequence the serving
/// planes execute, around the stand-in work.
#[inline]
fn traced_request(
    hub: &ObsHub,
    id: u64,
    buf: &mut [f32],
    rng: &mut Rng,
    sink: &mut u64,
) -> f64 {
    let t0 = Instant::now();
    let mut span = hub.begin();
    span.id = id;
    span.set(Stage::Parsed, hub.now_ns());
    span.set(Stage::Admitted, hub.now_ns());
    span.set(Stage::Dequeued, hub.now_ns());
    span.set(Stage::BatchFormed, hub.now_ns());
    span.set(Stage::InferStart, hub.now_ns());
    request_work(buf, rng, sink);
    span.set(Stage::InferDone, hub.now_ns());
    span.set(Stage::ReplyFlushed, hub.now_ns());
    hub.complete(&mut span, id as usize);
    zuluko::util::ms(t0.elapsed())
}

struct ModeState {
    name: &'static str,
    hub: ObsHub,
    rng: Rng,
    hist: Histogram,
    allocs: u64,
    requests: u64,
    sink: u64,
    next_id: u64,
}

impl ModeState {
    fn new(name: &'static str, rate: f64) -> ModeState {
        ModeState {
            name,
            hub: ObsHub::new(rate, 1024, 256, RINGS),
            rng: Rng::new(7),
            hist: Histogram::with_cap(65_536),
            allocs: 0,
            requests: 0,
            sink: 0,
            next_id: 0,
        }
    }

    /// Run one chunk of requests, attributing time + allocator events.
    fn chunk(&mut self, buf: &mut [f32], measured: bool) {
        let before = CountingAlloc::snapshot();
        for _ in 0..CHUNK {
            self.next_id += 1;
            let ms = traced_request(
                &self.hub,
                self.next_id,
                buf,
                &mut self.rng,
                &mut self.sink,
            );
            if measured {
                self.hist.record_ms(ms);
            }
        }
        if measured {
            let (a, _) = CountingAlloc::since(before);
            self.allocs += a;
            self.requests += CHUNK as u64;
        }
    }

    fn allocs_per_req(&self) -> f64 {
        self.allocs as f64 / (self.requests as f64).max(1.0)
    }

    fn row(&self) -> String {
        let (mean, p50, _, p99, max) = self.hist.summary();
        format!(
            "| {} | {:.2} | {:.4} | {:.4} | {:.4} | {:.4} |",
            self.name,
            self.allocs_per_req(),
            mean,
            p50,
            p99,
            max
        )
    }

    fn json(&self) -> Json {
        let (mean, p50, p95, p99, max) = self.hist.summary();
        let c = self.hub.counters();
        let mut o = Json::obj();
        o.set("name", self.name.into())
            .set("allocs_per_req", self.allocs_per_req().into())
            .set("requests", self.requests.into())
            .set("mean_ms", mean.into())
            .set("p50_ms", p50.into())
            .set("p95_ms", p95.into())
            .set("p99_ms", p99.into())
            .set("max_ms", max.into())
            .set("recorded", c.recorded.into())
            .set("sampled_out", c.sampled_out.into());
        o
    }
}

fn json_path() -> Option<String> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    argv.iter()
        .position(|a| a == "--json")
        .and_then(|i| argv.get(i + 1))
        .cloned()
}

fn main() {
    // `--iters` = measured chunks per mode (CHUNK requests each).
    let args = BenchArgs::from_env(96);
    let rounds = args.iters.max(1);
    let warmup_rounds = args.warmup.max(1);

    let mut modes = [
        ModeState::new("sampled_out", 0.0),
        ModeState::new("default", 0.01),
        ModeState::new("always", 1.0),
    ];
    let mut buf = vec![0.0f32; PER];

    println!(
        "== E14: tracing overhead, full 8-stamp span per request \
         ({} requests/mode) ==",
        rounds * CHUNK
    );
    // Alternating chunks: every mode sees the same machine conditions.
    for round in 0..warmup_rounds + rounds {
        let measured = round >= warmup_rounds;
        for m in modes.iter_mut() {
            m.chunk(&mut buf, measured);
        }
    }

    println!("| mode | allocs/req | mean ms | p50 ms | p99 ms | max ms |");
    println!("|---|---|---|---|---|---|");
    for m in &modes {
        println!("{}", m.row());
    }

    // Same seed, same math: tracing must not perturb the answers.
    assert_eq!(modes[0].sink, modes[1].sink, "modes diverged");
    assert_eq!(modes[0].sink, modes[2].sink, "always mode diverged");
    // The hubs really were in the modes they claim.
    assert_eq!(modes[0].hub.counters().recorded, 0);
    assert!(modes[1].hub.counters().recorded >= 1);
    assert_eq!(modes[2].hub.counters().sampled_out, 0);

    let (_, _, _, p99_out, _) = modes[0].hist.summary();
    let (_, _, _, p99_def, _) = modes[1].hist.summary();
    let p99_overhead = p99_def / p99_out.max(1e-9) - 1.0;
    let alloc_out = modes[0].allocs_per_req();
    let alloc_def = modes[1].allocs_per_req();
    let alloc_overhead = (alloc_def - alloc_out) / alloc_out.max(1.0);
    println!(
        "\ndefault sampling vs sampled-out: p99 {:+.2}%, allocs/request \
         {:+.2}% ({:.3} -> {:.3})",
        p99_overhead * 100.0,
        alloc_overhead * 100.0,
        alloc_out,
        alloc_def
    );

    if let Some(path) = json_path() {
        let mut cfg = Json::obj();
        cfg.set("requests_per_mode", (rounds * CHUNK).into())
            .set("input_elems", PER.into())
            .set("rings", RINGS.into())
            .set("quick", args.quick.into());
        let mut o = Json::obj();
        o.set("bench", "trace_overhead".into())
            .set("experiment", "E14".into())
            .set("config", cfg)
            .set(
                "modes",
                Json::Arr(modes.iter().map(|m| m.json()).collect()),
            )
            .set("p99_overhead_frac", p99_overhead.into())
            .set("alloc_overhead_frac", alloc_overhead.into());
        std::fs::write(&path, format!("{}\n", o.to_string())).expect("write bench json");
        println!("wrote {path}");
    }

    // Acceptance gate (ISSUE 7): ≤5% on both axes.  A `--quick` smoke
    // run has too few samples for a stable p99 quantile, so it gates
    // loosely — the full `make bench-json` run enforces the real bound.
    let p99_gate = if args.quick { 0.50 } else { 0.05 };
    assert!(
        p99_overhead <= p99_gate,
        "tracing p99 overhead {:.2}% exceeds {:.0}% (sampled_out \
         {p99_out:.4}ms, default {p99_def:.4}ms)",
        p99_overhead * 100.0,
        p99_gate * 100.0
    );
    assert!(
        alloc_overhead <= 0.05,
        "tracing alloc overhead {:.2}% exceeds 5% ({alloc_out:.3} -> \
         {alloc_def:.3} events/request)",
        alloc_overhead * 100.0
    );
}
