//! E17: AOT replica snapshots + predictive warm-up (DESIGN.md §11) —
//! cold start as a file read, not a rebuild.
//!
//! Three self-gating measurements over the sim engine (no artifacts or
//! XLA needed, so the gates run everywhere including CI):
//!
//! 1. **Replica construction**: snapshot path (`ReplicaSnapshot::load`
//!    -> `engine::build_from_snapshot`, warm-up covered by the captured
//!    warm plan) vs the cold path (`Manifest::load` -> `engine::build`
//!    -> `warmup()`).  Gate: snapshot construction >= 5x faster.  The
//!    sim per-image cost is pinned via `ZULUKO_SIM_EXEC_US` so the
//!    warm-up work the snapshot elides is deterministic, standing in
//!    for the graph build + first-inference warm-up a real backend
//!    pays (Table 2 of the paper: seconds, not microseconds).
//!
//! 2. **Cold-start economics on the serving stack**: p99 of the *first*
//!    request into a freshly booted coordinator (snapshot present,
//!    snapshots + prefetch on) vs steady-state warm p99.  Gate: cold
//!    first-request p99 <= 2x warm p99 — with snapshots, a cold boot is
//!    no longer a rebuild, just a small constant on top of one inference.
//!    The snapshot-less cold boot is measured and reported for contrast.
//!
//! 3. **Ablation**: steady-state serving with `--snapshots off` vs on.
//!    Gate: warm p99s within 1.5x either way — snapshots touch replica
//!    construction only, never the per-request path.
//!
//! Run: cargo bench --bench replica_snapshot [-- --quick] [--json PATH]

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use zuluko::bench::BenchArgs;
use zuluko::config::{Config, SnapshotMode};
use zuluko::coordinator::Coordinator;
use zuluko::engine::{self, sim::SIM_EXEC_ENV, EngineKind};
use zuluko::metrics::Histogram;
use zuluko::policy::Slo;
use zuluko::runtime::{Manifest, ReplicaSnapshot};
use zuluko::tensor::image::Image;
use zuluko::tensor::Tensor;
use zuluko::util::json::Json;

const HW: usize = 64;
const CLASSES: usize = 100;
const MODEL: &str = "m";
/// Pinned sim per-image busy-wait (µs): the deterministic stand-in for
/// the warm-up inference a cold build pays and a snapshot build skips.
const EXEC_US: u64 = 2000;

fn model_dir() -> PathBuf {
    let dir = std::env::temp_dir().join(format!("zuluko_bench_e17_{}", std::process::id()));
    zuluko::testkit::manifest::write_synthetic(&dir, MODEL, CLASSES, HW, &[1, 2, 4])
        .expect("write synthetic artifacts");
    dir
}

fn sim_cfg(dir: &Path, mode: SnapshotMode) -> Config {
    let mut cfg = Config {
        engine: EngineKind::Sim,
        workers: 1,
        max_batch: 1,
        batch_timeout: Duration::from_millis(1),
        queue_capacity: 64,
        ..Config::default()
    };
    cfg.policy.cache_capacity = 0; // every request runs an engine
    cfg.snapshots = mode;
    cfg.prefetch_threshold = 0.5;
    cfg.registry.upsert(MODEL, dir.to_path_buf());
    cfg.registry.default_model = Some(MODEL.to_string());
    cfg.validate().expect("bench config validates");
    cfg
}

fn frame_tensor(seed: u64) -> Tensor {
    let img = Image::synthetic(HW, HW, seed);
    let mut buf = vec![0.0f32; HW * HW * 3];
    img.to_input_into(&mut buf);
    Tensor::new(&[HW, HW, 3], buf).unwrap()
}

fn one_request(coord: &Coordinator, seed: u64) -> f64 {
    let t0 = Instant::now();
    let r = coord
        .submit_model(Some(MODEL), frame_tensor(seed), Slo::default())
        .unwrap()
        .recv()
        .unwrap();
    assert!(r.is_ok(), "bench request failed: {:?}", r.error);
    zuluko::util::ms(t0.elapsed())
}

fn p99(samples: &[f64]) -> f64 {
    let mut h = Histogram::default();
    for &s in samples {
        h.record_ms(s);
    }
    let (_, _, _, p99, _) = h.summary();
    p99
}

fn mean(samples: &[f64]) -> f64 {
    samples.iter().sum::<f64>() / samples.len().max(1) as f64
}

struct BuildRow {
    name: &'static str,
    mean_ms: f64,
    p99_ms: f64,
    builds: usize,
}

impl BuildRow {
    fn json(&self) -> Json {
        let mut o = Json::obj();
        o.set("name", self.name.into())
            .set("mean_ms", self.mean_ms.into())
            .set("p99_ms", self.p99_ms.into())
            .set("builds", self.builds.into());
        o
    }
}

/// The cold replica build exactly as the worker pays it on a
/// snapshot-miss: artifact read + parse, engine construction, warm-up.
fn cold_build(dir: &Path) -> f64 {
    let t0 = Instant::now();
    let m = Manifest::load(dir).expect("manifest loads");
    let mut eng = engine::build(EngineKind::Sim, &m).expect("cold build");
    eng.warmup().expect("warmup");
    zuluko::util::ms(t0.elapsed())
}

/// The snapshot build exactly as the worker pays it on a hit: load +
/// validate the file, build from pre-decoded state, and skip warm-up
/// when the captured warm plan covers this engine kind.
fn snapshot_build(dir: &Path) -> f64 {
    let t0 = Instant::now();
    let snap = ReplicaSnapshot::load(dir).expect("snapshot loads");
    let mut eng = engine::build_from_snapshot(EngineKind::Sim, &snap).expect("snapshot build");
    if !snap.warm_covers(EngineKind::Sim) {
        eng.warmup().expect("warmup");
    }
    zuluko::util::ms(t0.elapsed())
}

fn run_builds(
    name: &'static str,
    warmup: usize,
    iters: usize,
    f: impl Fn() -> f64,
) -> BuildRow {
    for _ in 0..warmup {
        f();
    }
    let samples: Vec<f64> = (0..iters).map(|_| f()).collect();
    BuildRow {
        name,
        mean_ms: mean(&samples),
        p99_ms: p99(&samples),
        builds: iters,
    }
}

/// Time the first request into `boots` freshly started coordinators.
fn cold_first_requests(dir: &Path, mode: SnapshotMode, boots: usize) -> Vec<f64> {
    (0..boots)
        .map(|i| {
            let coord = Coordinator::start(&sim_cfg(dir, mode)).expect("coordinator starts");
            let ms = one_request(&coord, 1000 + i as u64);
            coord.shutdown();
            ms
        })
        .collect()
}

/// Steady-state request latencies on one warm coordinator.
fn warm_requests(dir: &Path, mode: SnapshotMode, n: usize) -> Vec<f64> {
    let coord = Coordinator::start(&sim_cfg(dir, mode)).expect("coordinator starts");
    for i in 0..5 {
        one_request(&coord, i); // load the generation, settle caches
    }
    let samples = (0..n).map(|i| one_request(&coord, 2000 + i as u64)).collect();
    coord.shutdown();
    samples
}

fn json_path() -> Option<String> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    argv.iter()
        .position(|a| a == "--json")
        .and_then(|i| argv.get(i + 1))
        .cloned()
}

fn main() {
    // Pin the sim per-image cost before any engine exists so every mode
    // (cold, snapshot, serving) sees the same deterministic exec time.
    std::env::set_var(SIM_EXEC_ENV, EXEC_US.to_string());

    let args = BenchArgs::from_env(60);
    let build_iters = args.iters.max(3);
    let boots = if args.quick { 4 } else { 12 };
    let serve_n = if args.quick { 30 } else { 200 };

    let dir = model_dir();
    // Seed the snapshot the way the serving stack does: capture from the
    // live manifest with the sim warm plan, atomically written.
    let m = Manifest::load(&dir).expect("manifest loads");
    let snap = ReplicaSnapshot::capture(&m, &[EngineKind::Sim]).expect("capture");
    snap.write(&dir).expect("snapshot writes");
    let snap_bytes = std::fs::metadata(ReplicaSnapshot::path_for(&dir))
        .expect("snapshot file")
        .len();

    println!(
        "== E17: replica construction, snapshot vs cold (sim exec {EXEC_US} us/image, \
         {build_iters} builds/mode, snapshot {snap_bytes} B on disk) =="
    );
    let cold = run_builds("cold_build", args.warmup, build_iters, || cold_build(&dir));
    let snapb = run_builds("snapshot_build", args.warmup, build_iters, || {
        snapshot_build(&dir)
    });
    println!("| mode | mean ms | p99 ms |");
    println!("|---|---|---|");
    println!("| {} | {:.3} | {:.3} |", cold.name, cold.mean_ms, cold.p99_ms);
    println!("| {} | {:.3} | {:.3} |", snapb.name, snapb.mean_ms, snapb.p99_ms);
    let build_speedup = cold.mean_ms / snapb.mean_ms.max(1e-9);
    println!("snapshot build speedup: {build_speedup:.1}x");

    println!("\n== E17: cold-start economics on the serving stack ({boots} boots) ==");
    let on_first = cold_first_requests(&dir, SnapshotMode::On, boots);
    // Contrast: the same boots with snapshots off pay the full rebuild
    // (delete nothing — off never reads the file).
    let off_first = cold_first_requests(&dir, SnapshotMode::Off, boots);
    let on_warm = warm_requests(&dir, SnapshotMode::On, serve_n);
    let off_warm = warm_requests(&dir, SnapshotMode::Off, serve_n);
    let (on_first_p99, off_first_p99) = (p99(&on_first), p99(&off_first));
    let (on_warm_p99, off_warm_p99) = (p99(&on_warm), p99(&off_warm));
    println!("| path | p99 ms |");
    println!("|---|---|");
    println!("| first request, snapshots on  | {on_first_p99:.3} |");
    println!("| first request, snapshots off | {off_first_p99:.3} |");
    println!("| warm request, snapshots on   | {on_warm_p99:.3} |");
    println!("| warm request, snapshots off  | {off_warm_p99:.3} |");
    let cold_ratio = on_first_p99 / on_warm_p99.max(1e-9);
    let ablation_ratio = off_warm_p99 / on_warm_p99.max(1e-9);
    println!(
        "cold-first/warm p99 with snapshots: {cold_ratio:.2}x; warm-path \
         ablation off/on: {ablation_ratio:.2}x"
    );

    if let Some(path) = json_path() {
        let mut cfg = Json::obj();
        cfg.set("sim_exec_us", EXEC_US.into())
            .set("build_iters", build_iters.into())
            .set("boots", boots.into())
            .set("serve_requests", serve_n.into())
            .set("snapshot_bytes", (snap_bytes as usize).into())
            .set("input_hw", HW.into())
            .set("quick", args.quick.into());
        let mut serving = Json::obj();
        serving
            .set("cold_first_p99_ms_snapshots_on", on_first_p99.into())
            .set("cold_first_p99_ms_snapshots_off", off_first_p99.into())
            .set("warm_p99_ms_snapshots_on", on_warm_p99.into())
            .set("warm_p99_ms_snapshots_off", off_warm_p99.into());
        let mut gates = Json::obj();
        gates
            .set("build_speedup", build_speedup.into())
            .set("build_speedup_min", 5.0.into())
            .set("cold_first_over_warm_p99", cold_ratio.into())
            .set("cold_first_over_warm_p99_max", 2.0.into())
            .set("warm_ablation_off_over_on", ablation_ratio.into())
            .set("warm_ablation_tolerance", 1.5.into());
        let mut o = Json::obj();
        o.set("bench", "replica_snapshot".into())
            .set("experiment", "E17".into())
            .set("config", cfg)
            .set("modes", Json::Arr(vec![cold.json(), snapb.json()]))
            .set("serving", serving)
            .set("gates", gates);
        std::fs::write(&path, format!("{}\n", o.to_string())).expect("write bench json");
        println!("wrote {path}");
    }

    // ISSUE 10 gates.
    assert!(
        build_speedup >= 5.0,
        "snapshot-path replica construction must be >= 5x faster than a \
         cold build (got {build_speedup:.2}x: cold {:.3} ms, snapshot {:.3} ms)",
        cold.mean_ms,
        snapb.mean_ms
    );
    assert!(
        cold_ratio <= 2.0,
        "with snapshots + prefetch on, a cold model's first-request p99 \
         must be <= 2x the warm p99 (got {cold_ratio:.2}x: first \
         {on_first_p99:.3} ms, warm {on_warm_p99:.3} ms)"
    );
    assert!(
        ablation_ratio <= 1.5 && ablation_ratio >= 1.0 / 1.5,
        "snapshots must not change the steady-state serving path \
         (off/on warm p99 ratio {ablation_ratio:.2}x)"
    );
}
