//! E10 ablation: per-request heap allocation on the batched serving
//! path — pooled arena vs. unpooled (same code, `pool.enabled=false`)
//! vs. the legacy owned path (`Tensor::stack` / `unstack` / per-row
//! `Vec`s).
//!
//! Core result is a deterministic simulation of the worker hot loop that
//! needs no artifacts and no XLA: decode writes synthetic pixels into a
//! (leased or fresh) input buffer, the content key is hashed over the
//! borrowed pixels, the batch is assembled in place, an engine stand-in
//! produces `(B, 1000)` scores from the batch buffer, and reply
//! extraction mirrors the shipped worker — owned `topk(5)` per request
//! plus a response-cache fill with a cloned `CachedResult`.  Heap
//! traffic is counted by the `testkit::alloc::CountingAlloc` global-
//! allocator shim, so the numbers are real allocator events, not
//! estimates.  (Reply channels/sockets are outside the sim; they cost
//! the same in every mode.)
//!
//! What each mode measures:
//! * `pooled`   — the serving path as shipped: arena leases everywhere.
//! * `unpooled` — identical code with the arena disabled; every lease is
//!   a fresh allocation (the `--pool false` ablation flag).
//! * `legacy`   — the pre-arena path for reference: owned decode
//!   tensors, `Tensor::stack`, owned `unstack` rows.
//!
//! Acceptance gate (ISSUE 3): pooling must remove the pixel-plane
//! allocations.  Asserted two ways: (1) allocated **bytes**/request
//! drop >= 2x pooled vs unpooled (in practice >100x — the pooled
//! buffers are the ~618 KB decode and ~2.4 MB batch allocations, while
//! what remains is tens-of-bytes control-plane), and (2) allocation
//! **events**/request drop by >= 1.0 absolute — exactly the decode
//! lease (1/req) plus the batch lease (1/B per req) that the arena
//! turns into hits.  Small per-request control-plane allocations
//! (top-5 vec, cache clone) are identical in both modes by
//! construction, so an event *ratio* would understate what pooling
//! does; the bytes ratio and the absolute event delta state it
//! exactly.
//!
//! E15 rider (the wire plane): the same binary also measures the
//! socket-to-reply request path under both wire parsers — the tape
//! scanner (`--wire-parser tape`, default) vs the legacy tree parser —
//! over an identical pre-rendered request stream.  Replies must be
//! byte-for-byte identical (asserted via a hash over every reply
//! line); the parsers may differ only in ingest allocations and
//! latency.  The 50% gate applies to the **ingest segment** (framing +
//! parse + wire key + cache probe) — the exact work the tape scanner
//! replaces; decode/infer/reply-serialization allocations are identical
//! in both modes by construction and are reported in the totals.
//!
//! E16 rider (the frame lane, ISSUE 9): pixel ingest over the binary
//! frame lane (header line + length-prefixed raw payload, reassembled
//! by the planes' `Framing` machine and decoded straight from the
//! borrowed payload) vs the counterfactual JSON-embedded-pixels
//! encoding (the same pixels as a JSON number array, tree-parsed and
//! collected into an owned byte vec before decode).  Same pixels per
//! request in both modes, so replies must be byte-identical (hash
//! sink).  Gates: the frame lane must ingest >= 2x fewer wire
//! bytes/request and allocate >= 50% fewer events on the ingest
//! segment (framing + parse + pixels-to-tensor).
//!
//! Run: cargo bench --bench hot_path_alloc [-- --quick] [--json PATH]

use std::time::Instant;

use zuluko::bench::BenchArgs;
use zuluko::config::WireParser;
use zuluko::coordinator::Response;
use zuluko::metrics::Histogram;
use zuluko::policy::{bytes_key, image_key, CachedResult, ResponseCache};
use zuluko::server::client::InferRequest;
use zuluko::server::conn::{Framing, WireItem};
use zuluko::server::protocol::{self, ClientMsg, ImageSpec};
use zuluko::tensor::image::Image;
use zuluko::tensor::{Lease, Tensor, TensorPool, TensorView};
use zuluko::testkit::alloc::CountingAlloc;
use zuluko::testkit::rng::Rng;
use zuluko::util::json::Json;
use zuluko::util::wire::WireTape;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

const HW: usize = 227;
const PER: usize = HW * HW * 3;
const CLASSES: usize = 1000;
const BATCH: usize = 4;
const CACHE_CAP: usize = 256;

// E16 frame-ingest modes use a smaller square so the JSON-embedded
// baseline (roughly 4 chars per pixel byte) stays cheap to pre-render.
const FHW: usize = 32;
const FPER: usize = FHW * FHW * 3;
const FRAME_LINE_MAX: usize = 64 * 1024;
const FRAME_MAX: usize = 8 * 1024 * 1024;

/// Synthetic "decode": fill the input buffer in place (models
/// `Image::to_input_into` writing into a pooled lease).
fn decode_into(buf: &mut [f32], rng: &mut Rng) {
    for v in buf.iter_mut() {
        *v = rng.uniform(-1.0, 1.0) as f32;
    }
}

/// Engine stand-in: deterministic per-row scores from the batch buffer
/// (`tensor_from_literal` allocates the output in the real path, so the
/// scores vec is owned in every mode).
fn fake_infer(batch: TensorView<'_>, scores: &mut [f32]) {
    let b = batch.num_rows();
    for slot in 0..b {
        let row = batch.row(slot).data();
        let s = row[0] + row[row.len() - 1];
        for c in 0..CLASSES {
            scores[slot * CLASSES + c] = s + c as f32 * 1e-3;
        }
    }
}

/// Reply extraction exactly as the shipped worker does it: owned top-5
/// per request plus a cache fill with a cloned result.
fn extract(row: TensorView<'_>, key: u64, cache: &ResponseCache, sink: &mut u64) {
    let top1 = row.argmax();
    let top5 = row.topk(5);
    cache.put(
        key,
        CachedResult {
            top1,
            top5: top5.clone(),
        },
    );
    *sink = sink.wrapping_add((top1 + top5[0].0) as u64);
}

struct ModeResult {
    name: &'static str,
    allocs_per_req: f64,
    bytes_per_req: f64,
    rps: f64,
    p50_ms: f64,
    p99_ms: f64,
    sink: u64,
}

impl ModeResult {
    fn row(&self) -> String {
        format!(
            "| {} | {:.2} | {:.0} | {:.0} | {:.3} | {:.3} |",
            self.name,
            self.allocs_per_req,
            self.bytes_per_req,
            self.rps,
            self.p50_ms,
            self.p99_ms
        )
    }

    fn json(&self) -> Json {
        let mut o = Json::obj();
        o.set("name", self.name.into())
            .set("allocs_per_req", self.allocs_per_req.into())
            .set("bytes_per_req", self.bytes_per_req.into())
            .set("throughput_rps", self.rps.into())
            .set("p50_ms", self.p50_ms.into())
            .set("p99_ms", self.p99_ms.into());
        o
    }
}

/// The zero-copy worker loop (pooled or unpooled is purely the arena
/// flag — same code, same order of operations).
fn run_arena_mode(name: &'static str, pooled: bool, warmup: usize, waves: usize) -> ModeResult {
    let pool = TensorPool::with_mode(pooled, 16);
    let cache = ResponseCache::new(CACHE_CAP);
    let mut rng = Rng::new(7);
    let mut images: Vec<(u64, Lease)> = Vec::with_capacity(BATCH);
    let mut samples: Vec<f64> = Vec::with_capacity(waves * BATCH);
    let bshape = [BATCH, HW, HW, 3];
    let sshape = [BATCH, CLASSES];
    let mut sink = 0u64;
    let mut before = CountingAlloc::snapshot();
    let mut t_start = Instant::now();

    for wave in 0..warmup + waves {
        if wave == warmup {
            before = CountingAlloc::snapshot();
            t_start = Instant::now();
        }
        let t0 = Instant::now();
        // Decode each request straight into a leased input buffer, and
        // hash the borrowed pixels for the response-cache key.
        images.clear();
        for _ in 0..BATCH {
            let mut l = pool.lease(PER);
            decode_into(&mut l, &mut rng);
            let key = image_key(&l);
            images.push((key, l));
        }
        // In-place batching: rows copied into one leased batch buffer.
        let mut bbuf = pool.lease(BATCH * PER);
        for (slot, (_, img)) in images.iter().enumerate() {
            bbuf[slot * PER..(slot + 1) * PER].copy_from_slice(img);
        }
        // Owned engine output, like tensor_from_literal.
        let mut scores = vec![0.0f32; BATCH * CLASSES];
        fake_infer(TensorView::new(&bshape, &bbuf), &mut scores);
        drop(bbuf);
        // Reply extraction on borrowed output rows.
        let sv = TensorView::new(&sshape, &scores);
        for (slot, (key, _)) in images.iter().enumerate() {
            extract(sv.row(slot), *key, &cache, &mut sink);
        }
        if wave >= warmup {
            let ms = zuluko::util::ms(t0.elapsed());
            for _ in 0..BATCH {
                samples.push(ms);
            }
        }
    }

    finish(name, before, t_start, samples, waves, sink)
}

/// The pre-arena path: owned tensors end to end.
fn run_legacy_mode(warmup: usize, waves: usize) -> ModeResult {
    let cache = ResponseCache::new(CACHE_CAP);
    let mut rng = Rng::new(7);
    let mut images: Vec<(u64, Tensor)> = Vec::with_capacity(BATCH);
    let mut samples: Vec<f64> = Vec::with_capacity(waves * BATCH);
    let rshape = [HW, HW, 3];
    let mut sink = 0u64;
    let mut before = CountingAlloc::snapshot();
    let mut t_start = Instant::now();

    for wave in 0..warmup + waves {
        if wave == warmup {
            before = CountingAlloc::snapshot();
            t_start = Instant::now();
        }
        let t0 = Instant::now();
        images.clear();
        for _ in 0..BATCH {
            let mut data = vec![0.0f32; PER];
            decode_into(&mut data, &mut rng);
            let key = image_key(&data);
            images.push((key, Tensor::new(&rshape, data).unwrap()));
        }
        let refs: Vec<&Tensor> = images.iter().map(|(_, t)| t).collect();
        let batch = Tensor::stack(&refs).unwrap();
        let mut scores = vec![0.0f32; BATCH * CLASSES];
        fake_infer(batch.view(), &mut scores);
        let st = Tensor::new(&[BATCH, CLASSES], scores).unwrap();
        // Old extraction: one owned Vec per unstacked row.
        for (row, (key, _)) in st.unstack().unwrap().iter().zip(images.iter()) {
            extract(row.view(), *key, &cache, &mut sink);
        }
        if wave >= warmup {
            let ms = zuluko::util::ms(t0.elapsed());
            for _ in 0..BATCH {
                samples.push(ms);
            }
        }
    }

    finish("legacy", before, t_start, samples, waves, sink)
}

/// Deterministic request stream for the wire modes: a bounded seed set
/// so repeats hit the wire-key cache (the duplicated-frame case the
/// tape fast path targets), with enough field and spelling variety to
/// exercise both parsers' full grammar — optional SLO fields, model
/// names (plain and escaped), a non-canonical number spelling, and
/// leading whitespace.
fn wire_request_line(i: usize) -> Vec<u8> {
    let seed = (i * 31) % 96;
    match i % 5 {
        0 => format!(
            "{{\"id\":{i},\"image\":{{\"synthetic\":{seed}}},\
             \"deadline_ms\":2500,\"priority\":\"hi\"}}"
        ),
        1 => format!(
            "  {{\"id\":{i},\"image\":{{\"synthetic\":{seed}}},\
             \"model\":\"squeezenet\"}}"
        ),
        // Non-canonical number spelling: the tape's span fast path must
        // fall back to re-formatting the seed, and still agree with the
        // tree parser's key.
        2 => format!("{{\"id\":{i},\"image\":{{\"synthetic\":{seed}e0}}}}"),
        3 => format!(
            "{{\"id\":{i},\"image\":{{\"synthetic\":{seed}}},\
             \"model\":\"sq\\u0075eezenet\"}}"
        ),
        _ => format!("{{\"id\":{i},\"image\":{{\"synthetic\":{seed}}}}}"),
    }
    .into_bytes()
}

/// The socket-to-reply loop, parameterized by wire parser (E15).
/// Mirrors the per-request life on an IO lane: framing is already done
/// (both planes frame with `next_line_span`, which never allocates),
/// then parse + wire key -> cache probe -> on a miss decode into a
/// pooled lease, infer, extract, cache fill -> serialize the reply.
/// Reply timing fields are pinned to 0.0 so tape and tree replies can
/// be compared byte for byte via the reply-hash sink.
///
/// Returns the mode result plus the ingest (parse + wire key)
/// allocation events per request — the segment the tape scanner
/// replaces; everything downstream is identical in both modes by
/// construction.
fn run_wire_mode(
    name: &'static str,
    parser: WireParser,
    warmup: usize,
    waves: usize,
) -> (ModeResult, f64) {
    let pool = TensorPool::with_mode(true, 16);
    let cache = ResponseCache::new(CACHE_CAP);
    let mut tape = WireTape::new();
    let model: std::sync::Arc<str> = std::sync::Arc::from("squeezenet");
    let lines: Vec<Vec<u8>> = (0..(warmup + waves) * BATCH)
        .map(wire_request_line)
        .collect();
    let mut samples: Vec<f64> = Vec::with_capacity(waves * BATCH);
    let mut scores = vec![0.0f32; CLASSES];
    let mut sink = 0u64;
    let mut ingest_allocs = 0u64;
    let mut before = CountingAlloc::snapshot();
    let mut t_start = Instant::now();

    for wave in 0..warmup + waves {
        if wave == warmup {
            before = CountingAlloc::snapshot();
            t_start = Instant::now();
            ingest_allocs = 0;
        }
        for slot in 0..BATCH {
            let line: &[u8] = &lines[wave * BATCH + slot];
            let t0 = Instant::now();
            // Ingest: the segment the tape scanner replaces.
            let s0 = CountingAlloc::snapshot();
            let (msg, wire_key) = match protocol::parse_line(parser, line, &mut tape) {
                Ok(parsed) => parsed,
                Err(e) => panic!("bench request line rejected: {e}"),
            };
            ingest_allocs += CountingAlloc::since(s0).0;
            let (id, image) = match msg {
                ClientMsg::Infer { id, image, .. } => (id, image),
                _ => panic!("bench line parsed as a non-infer message"),
            };
            // The rest of the request's life is identical in both modes.
            let (top1, top5, cached) = match wire_key.and_then(|k| cache.peek(k)) {
                Some(c) => (c.top1, c.top5, true),
                None => {
                    let seed = match &image {
                        ImageSpec::Synthetic(s) => *s,
                        ImageSpec::Ppm(_) | ImageSpec::Frame(_) => 0,
                    };
                    let mut l = pool.lease(PER);
                    decode_into(&mut l, &mut Rng::new(seed.wrapping_add(1)));
                    fake_infer(TensorView::new(&[1, HW, HW, 3], &l), &mut scores);
                    let sv = TensorView::new(&[1, CLASSES], &scores);
                    let row = sv.row(0);
                    let (top1, top5) = (row.argmax(), row.topk(5));
                    if let Some(k) = wire_key {
                        cache.put(
                            k,
                            CachedResult {
                                top1,
                                top5: top5.clone(),
                            },
                        );
                    }
                    (top1, top5, false)
                }
            };
            let reply = protocol::response_line(&Response {
                id,
                top1,
                top5,
                queue_ms: 0.0,
                exec_ms: 0.0,
                total_ms: 0.0,
                batch_size: 1,
                worker: 0,
                engine: "sim",
                model: model.clone(),
                cached,
                kind: "",
                error: None,
                span: None,
            });
            sink = sink.wrapping_add(bytes_key(reply.as_bytes()));
            if wave >= warmup {
                samples.push(zuluko::util::ms(t0.elapsed()));
            }
        }
    }

    let res = finish(name, before, t_start, samples, waves, sink);
    let ingest_per_req = ingest_allocs as f64 / (waves * BATCH) as f64;
    (res, ingest_per_req)
}

/// Deterministic pixels for E16 request `i` — shared by both encodings
/// so the reply hashes can be compared byte for byte.
fn frame_pixels(i: usize) -> Vec<u8> {
    let mut r = Rng::new(0xE16 ^ i as u64);
    (0..FPER).map(|_| (r.next_u64() & 0xff) as u8).collect()
}

/// The counterfactual JSON-embedded encoding: the same pixels as a
/// number array inside the request line.
fn json_pixels_wire(i: usize, px: &[u8]) -> Vec<u8> {
    use std::fmt::Write as _;
    let mut s = String::with_capacity(px.len() * 4 + 96);
    let _ = write!(
        s,
        "{{\"id\":{i},\"image\":{{\"pixels\":{{\"h\":{FHW},\"w\":{FHW},\"c\":3,\"data\":["
    );
    for (k, b) in px.iter().enumerate() {
        if k > 0 {
            s.push(',');
        }
        let _ = write!(s, "{b}");
    }
    s.push_str("]}}}\n");
    s.into_bytes()
}

/// The frame-lane encoding: the shipped client builder's header line
/// plus the raw payload, exactly as it goes on a socket.
fn frame_wire(i: usize, px: &[u8]) -> Vec<u8> {
    let req = InferRequest::new(i as u64).frame(FHW, FHW, 3, px);
    let (line, payload) = req.request_line().expect("frame request renders");
    let mut wire = line.into_bytes();
    wire.push(b'\n');
    wire.extend_from_slice(payload.expect("frame request carries a payload"));
    wire
}

/// E16: pixel ingest, frame lane vs JSON-embedded pixels.  Ingest is
/// everything from wire bytes to a ready `(1, FHW, FHW, 3)` input
/// tensor: framing + parse + pixel materialization + decode-into-lease.
/// Downstream (infer, extract, reply serialization) is shared code.
/// Returns (result, ingest allocs/req, wire bytes/req).
fn run_ingest_mode(
    name: &'static str,
    binary: bool,
    warmup: usize,
    waves: usize,
) -> (ModeResult, f64, f64) {
    let pool = TensorPool::with_mode(true, 16);
    let mut tape = WireTape::new();
    let mut framing = Framing::new();
    let model: std::sync::Arc<str> = std::sync::Arc::from("squeezenet");
    let streams: Vec<Vec<u8>> = (0..(warmup + waves) * BATCH)
        .map(|i| {
            let px = frame_pixels(i);
            if binary {
                frame_wire(i, &px)
            } else {
                json_pixels_wire(i, &px)
            }
        })
        .collect();
    let mut samples: Vec<f64> = Vec::with_capacity(waves * BATCH);
    let mut scores = vec![0.0f32; CLASSES];
    let mut sink = 0u64;
    let mut ingest_allocs = 0u64;
    let mut wire_bytes = 0u64;
    let mut before = CountingAlloc::snapshot();
    let mut t_start = Instant::now();

    for wave in 0..warmup + waves {
        if wave == warmup {
            before = CountingAlloc::snapshot();
            t_start = Instant::now();
            ingest_allocs = 0;
            wire_bytes = 0;
        }
        for slot in 0..BATCH {
            let idx = wave * BATCH + slot;
            let buf: &[u8] = &streams[idx];
            wire_bytes += buf.len() as u64;
            let t0 = Instant::now();
            let s0 = CountingAlloc::snapshot();
            let (id, lease) = if binary {
                // Frame lane: reassemble with the planes' framing
                // machine, tape-parse the header, decode straight from
                // the borrowed payload — no owned pixel copy.
                let span = match framing.next_item(buf, 0, FRAME_LINE_MAX) {
                    Ok(Some(WireItem::Line(span))) => span,
                    other => panic!("expected the header line, got {other:?}"),
                };
                let line_end = span.end;
                let line_bytes = &buf[span.start..line_end];
                let (msg, key) = protocol::parse_line(WireParser::Tape, line_bytes, &mut tape)
                    .expect("frame header line parses");
                assert_eq!(key, None, "frames are never wire-keyed");
                let (id, fh) = match msg {
                    ClientMsg::Infer {
                        id,
                        image: ImageSpec::Frame(fh),
                        ..
                    } => (id, fh),
                    other => panic!("expected a frame infer, got {other:?}"),
                };
                fh.check(FRAME_MAX).expect("bench header is valid");
                framing.expect_payload(fh.len);
                let payload = match framing.next_item(buf, line_end + 1, FRAME_LINE_MAX) {
                    Ok(Some(WireItem::Frame(range))) => &buf[range],
                    other => panic!("expected the payload, got {other:?}"),
                };
                let mut l = pool.lease(FPER);
                Image::frame_to_input_into(payload, FHW, FHW, &mut l, FHW);
                (id, l)
            } else {
                // JSON-embedded baseline: tree-parse the line (one node
                // per pixel), collect the array into an owned byte vec,
                // then the same decode.
                let text = std::str::from_utf8(buf).expect("json line is utf-8");
                let j = Json::parse(text.trim_end()).expect("json pixels line parses");
                let id = j.get("id").and_then(Json::as_f64).expect("id present") as u64;
                let data = match j
                    .get("image")
                    .and_then(|im| im.get("pixels"))
                    .and_then(|p| p.get("data"))
                {
                    Some(Json::Arr(a)) => a,
                    other => panic!("expected a pixel array, got {other:?}"),
                };
                let px: Vec<u8> = data
                    .iter()
                    .map(|v| v.as_f64().expect("pixel is a number") as u8)
                    .collect();
                let mut l = pool.lease(FPER);
                Image::frame_to_input_into(&px, FHW, FHW, &mut l, FHW);
                (id, l)
            };
            ingest_allocs += CountingAlloc::since(s0).0;
            // Downstream of ingest: identical in both modes.
            fake_infer(TensorView::new(&[1, FHW, FHW, 3], &lease), &mut scores);
            let sv = TensorView::new(&[1, CLASSES], &scores);
            let row = sv.row(0);
            let (top1, top5) = (row.argmax(), row.topk(5));
            let reply = protocol::response_line(&Response {
                id,
                top1,
                top5,
                queue_ms: 0.0,
                exec_ms: 0.0,
                total_ms: 0.0,
                batch_size: 1,
                worker: 0,
                engine: "sim",
                model: model.clone(),
                cached: false,
                kind: "",
                error: None,
                span: None,
            });
            sink = sink.wrapping_add(bytes_key(reply.as_bytes()));
            if wave >= warmup {
                samples.push(zuluko::util::ms(t0.elapsed()));
            }
        }
    }

    let res = finish(name, before, t_start, samples, waves, sink);
    let n_req = (waves * BATCH) as f64;
    (res, ingest_allocs as f64 / n_req, wire_bytes as f64 / n_req)
}

fn finish(
    name: &'static str,
    before: (u64, u64),
    t_start: Instant,
    samples: Vec<f64>,
    waves: usize,
    sink: u64,
) -> ModeResult {
    let wall = t_start.elapsed();
    let (allocs, bytes) = CountingAlloc::since(before);
    let n_req = (waves * BATCH) as f64;
    let mut h = Histogram::default();
    for &s in &samples {
        h.record_ms(s);
    }
    let (_, p50, _, p99, _) = h.summary();
    ModeResult {
        name,
        allocs_per_req: allocs as f64 / n_req,
        bytes_per_req: bytes as f64 / n_req,
        rps: n_req / wall.as_secs_f64().max(1e-9),
        p50_ms: p50,
        p99_ms: p99,
        sink,
    }
}

fn json_path() -> Option<String> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    argv.iter()
        .position(|a| a == "--json")
        .and_then(|i| argv.get(i + 1))
        .cloned()
}

fn main() {
    // `--iters` = measured batch waves per mode, `--warmup` = warmup
    // waves; `--quick` clamps both for the CI smoke run.
    let args = BenchArgs::from_env(96);
    let waves = args.iters.max(1);
    let warmup = args.warmup;

    println!(
        "== E10: per-request heap allocation, wire -> engine -> reply \
         (batch={BATCH}, {} requests/mode) ==",
        waves * BATCH
    );
    let pooled = run_arena_mode("pooled", true, warmup, waves);
    let unpooled = run_arena_mode("unpooled", false, warmup, waves);
    let legacy = run_legacy_mode(warmup, waves);

    println!("| mode | allocs/req | bytes/req | req/s | p50 ms | p99 ms |");
    println!("|---|---|---|---|---|---|");
    println!("{}", pooled.row());
    println!("{}", unpooled.row());
    println!("{}", legacy.row());

    // Same seed, same math: every mode must compute the same answers.
    assert_eq!(pooled.sink, unpooled.sink, "modes diverged");
    assert_eq!(pooled.sink, legacy.sink, "legacy path diverged");

    let bytes_reduction = unpooled.bytes_per_req / pooled.bytes_per_req.max(1e-9);
    let event_delta = unpooled.allocs_per_req - pooled.allocs_per_req;
    println!(
        "\npooled vs unpooled: {bytes_reduction:.1}x fewer allocated bytes per \
         request; {event_delta:.2} fewer allocation events per request \
         (the decode + batch leases)"
    );
    println!(
        "pooled vs legacy:   {:.1}x fewer allocated bytes per request",
        legacy.bytes_per_req / pooled.bytes_per_req.max(1e-9)
    );

    println!(
        "\n== E15: socket-to-reply wire plane, tape vs tree parser \
         ({} requests/mode) ==",
        waves * BATCH
    );
    let (wire_tape, tape_ingest) = run_wire_mode("wire_tape", WireParser::Tape, warmup, waves);
    let (wire_tree, tree_ingest) = run_wire_mode("wire_tree", WireParser::Tree, warmup, waves);
    println!("| mode | allocs/req | bytes/req | req/s | p50 ms | p99 ms |");
    println!("|---|---|---|---|---|---|");
    println!("{}", wire_tape.row());
    println!("{}", wire_tree.row());
    println!(
        "ingest (parse + wire key) allocs/req: tape {tape_ingest:.2}, \
         tree {tree_ingest:.2}"
    );

    // Byte-for-byte criterion: the sink is a content hash over every
    // reply line, so equality means both parsers answered every request
    // with identical bytes.
    assert_eq!(
        wire_tape.sink, wire_tree.sink,
        "wire parsers' replies diverged"
    );

    println!(
        "\n== E16: pixel ingest, binary frame lane vs JSON-embedded \
         pixels ({FHW}x{FHW}x3, {} requests/mode) ==",
        waves * BATCH
    );
    let (ing_frame, frame_ingest, frame_bytes) =
        run_ingest_mode("ingest_frame", true, warmup, waves);
    let (ing_json, json_ingest, json_bytes) =
        run_ingest_mode("ingest_json_pixels", false, warmup, waves);
    println!("| mode | allocs/req | bytes/req | req/s | p50 ms | p99 ms |");
    println!("|---|---|---|---|---|---|");
    println!("{}", ing_frame.row());
    println!("{}", ing_json.row());
    let frame_bytes_reduction = json_bytes / frame_bytes.max(1e-9);
    println!(
        "wire bytes/req: frame {frame_bytes:.0}, json {json_bytes:.0} \
         ({frame_bytes_reduction:.1}x fewer on the frame lane); ingest \
         allocs/req: frame {frame_ingest:.2}, json {json_ingest:.2}"
    );

    // Same pixels, same downstream code: the reply streams must match
    // byte for byte across the two encodings.
    assert_eq!(
        ing_frame.sink, ing_json.sink,
        "frame-lane and JSON-pixel replies diverged"
    );

    if let Some(path) = json_path() {
        let mut cfg = Json::obj();
        cfg.set("requests_per_mode", (waves * BATCH).into())
            .set("batch", BATCH.into())
            .set("input_elems", PER.into())
            .set("cache_capacity", CACHE_CAP.into())
            .set("quick", args.quick.into());
        let mut tape_row = wire_tape.json();
        tape_row.set("ingest_allocs_per_req", tape_ingest.into());
        let mut tree_row = wire_tree.json();
        tree_row.set("ingest_allocs_per_req", tree_ingest.into());
        let mut wire = Json::obj();
        wire.set("replies_byte_identical", true.into()).set(
            "ingest_alloc_events_removed_frac",
            (1.0 - tape_ingest / tree_ingest.max(1e-9)).into(),
        );
        let mut frame_row = ing_frame.json();
        frame_row
            .set("ingest_allocs_per_req", frame_ingest.into())
            .set("wire_bytes_per_req", frame_bytes.into());
        let mut json_row = ing_json.json();
        json_row
            .set("ingest_allocs_per_req", json_ingest.into())
            .set("wire_bytes_per_req", json_bytes.into());
        let mut frames = Json::obj();
        frames
            .set("replies_byte_identical", true.into())
            .set("wire_bytes_reduction", frame_bytes_reduction.into())
            .set(
                "ingest_alloc_events_removed_frac",
                (1.0 - frame_ingest / json_ingest.max(1e-9)).into(),
            );
        let mut o = Json::obj();
        o.set("bench", "hot_path_alloc".into())
            .set("experiment", "E10+E15+E16".into())
            .set("config", cfg)
            .set(
                "modes",
                Json::Arr(vec![
                    pooled.json(),
                    unpooled.json(),
                    legacy.json(),
                    tape_row,
                    tree_row,
                    frame_row,
                    json_row,
                ]),
            )
            .set("bytes_reduction_pooled_vs_unpooled", bytes_reduction.into())
            .set("alloc_event_delta_per_req", event_delta.into())
            .set("wire", wire)
            .set("frames", frames);
        std::fs::write(&path, format!("{}\n", o.to_string())).expect("write bench json");
        println!("wrote {path}");
    }

    assert!(
        bytes_reduction >= 2.0,
        "pooling must at least halve allocated bytes/request \
         (got {bytes_reduction:.2}x: pooled {:.0} B, unpooled {:.0} B)",
        pooled.bytes_per_req,
        unpooled.bytes_per_req
    );
    assert!(
        event_delta >= 1.0,
        "pooling must eliminate at least the per-request decode lease \
         (delta {event_delta:.2}: pooled {:.2}, unpooled {:.2})",
        pooled.allocs_per_req,
        unpooled.allocs_per_req
    );
    // ISSUE 8 gate: the tape scanner must remove at least half the
    // per-request allocation events on the infer hot path's ingest
    // segment (in practice it removes nearly all of them — what remains
    // is the owned model-name copy on the minority of requests that
    // carry one).
    assert!(
        tape_ingest <= 0.5 * tree_ingest,
        "tape ingest must at least halve allocation events/request \
         (tape {tape_ingest:.2}, tree {tree_ingest:.2})"
    );
    // ISSUE 9 gates: the binary frame lane must at least halve the
    // ingested wire bytes per request vs JSON-embedded pixels (in
    // practice ~4x — JSON spends several chars per pixel byte), and at
    // least halve the allocation events on the ingest segment (the
    // tree's per-pixel nodes plus the owned pixel vec all disappear;
    // what remains is the pooled lease bookkeeping).
    assert!(
        frame_bytes_reduction >= 2.0,
        "frame lane must at least halve ingested bytes/request \
         (got {frame_bytes_reduction:.2}x: frame {frame_bytes:.0} B, \
         json {json_bytes:.0} B)"
    );
    assert!(
        frame_ingest <= 0.5 * json_ingest,
        "frame ingest must at least halve allocation events/request \
         (frame {frame_ingest:.2}, json {json_ingest:.2})"
    );
}
