//! E9 ablation: SLO attainment of fixed-engine serving vs the adaptive
//! policy layer under a bursty trace (DESIGN.md §7).
//!
//! Core result is a deterministic discrete-event simulation driven by the
//! *real* policy components (`LatencyPredictor` + `Selector`) over engine
//! latency models drawn from the paper (Fig 3 ACL ≈ 320 ms/image, Fig 4
//! int8 ≈ 110 ms/image), so it runs on any machine with no artifacts:
//!
//! * fixed-acl: one fp32 pool — collapses under 10 rps offered (capacity
//!   ≈ 3 rps), nearly every deadline blown;
//! * fixed-quant: one int8 pool — capacity ≈ 9 rps, so backlog grows a
//!   little every burst and tight deadlines start missing;
//! * adaptive: deadline-aware selection across both pools — tight
//!   requests ride the int8 path, loose ones keep the fp32 path busy,
//!   and requests no variant can serve are shed instead of executed late.
//!
//! A second section replays a short burst against the real coordinator
//! when artifacts exist (skipped otherwise).
//!
//! Run: cargo bench --bench policy_slo [-- --quick]

use std::time::Duration;

use zuluko::bench::BenchArgs;
use zuluko::engine::EngineKind;
use zuluko::policy::{Decision, LatencyPredictor, PoolView, Selector, Slo};
use zuluko::testkit::rng::Rng;
use zuluko::trace::{Pattern, Trace};
use zuluko::util::percentile_sorted;

/// Per-pool queue slots (mirrors Config::queue_capacity scaled down).
const CAP: usize = 8;
/// Paper-derived per-image latency models, ms.
const ACL_MS: f64 = 320.0;
const QUANT_MS: f64 = 110.0;

/// One synthetic request: arrival offset, deadline, and a latency jitter
/// factor shared by every policy so all three replay identical load.
struct Req {
    at_ms: f64,
    deadline_ms: f64,
    jitter: f64,
}

/// Single-worker FIFO pool model: completion = max(arrival, tail) + exec.
struct SimPool {
    kind: EngineKind,
    base_ms: f64,
    completions: Vec<f64>,
}

impl SimPool {
    fn new(kind: EngineKind, base_ms: f64) -> SimPool {
        SimPool {
            kind,
            base_ms,
            completions: Vec::new(),
        }
    }

    fn queued(&self, now: f64) -> usize {
        self.completions.iter().filter(|&&c| c > now).count()
    }

    fn run(&mut self, now: f64, exec_ms: f64) -> f64 {
        let tail = self.completions.last().copied().unwrap_or(0.0);
        let done = tail.max(now) + exec_ms;
        self.completions.push(done);
        done
    }
}

#[derive(Default)]
struct Outcome {
    met: usize,
    missed: usize,
    shed: usize,
    wasted_ms: f64,
    served_lat_ms: Vec<f64>,
}

impl Outcome {
    fn total(&self) -> usize {
        self.met + self.missed + self.shed
    }

    fn attainment(&self) -> f64 {
        if self.total() == 0 {
            0.0
        } else {
            self.met as f64 / self.total() as f64
        }
    }

    fn row(&self, name: &str) -> String {
        let mut lats = self.served_lat_ms.clone();
        lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
        format!(
            "| {} | {:.1}% | {} | {} | {} | {:.0} | {:.0} |",
            name,
            self.attainment() * 100.0,
            self.met,
            self.missed,
            self.shed,
            percentile_sorted(&lats, 95.0),
            self.wasted_ms
        )
    }
}

/// `fixed`: always use pool `i` (shed only when its queue is full).
/// `None`: adaptive — the real Selector over the real predictor.
fn run_sim(reqs: &[Req], fixed: Option<usize>) -> Outcome {
    let mut pools = vec![
        SimPool::new(EngineKind::AclStaged, ACL_MS),
        SimPool::new(EngineKind::Quant, QUANT_MS),
    ];
    let pred = LatencyPredictor::new(0.3);
    for p in &pools {
        pred.seed(p.kind, 1, p.base_ms);
    }
    let sel = Selector::new(1.1, 1);

    let mut out = Outcome::default();
    for req in reqs {
        let now = req.at_ms;
        let choice = match fixed {
            Some(i) => {
                if pools[i].queued(now) >= CAP {
                    None
                } else {
                    Some(i)
                }
            }
            None => {
                let views: Vec<PoolView> = pools
                    .iter()
                    .map(|p| PoolView {
                        kind: p.kind,
                        queued: p.queued(now),
                        workers: 1,
                        capacity: CAP,
                    })
                    .collect();
                let slo = Slo::with_deadline_ms(req.deadline_ms);
                match sel.choose(&pred, &views, &slo, Some(req.deadline_ms)) {
                    Decision::Route { pool, .. } => Some(pool),
                    Decision::Shed { .. } => None,
                }
            }
        };
        match choice {
            None => out.shed += 1,
            Some(i) => {
                let exec_ms = pools[i].base_ms * req.jitter;
                let done = pools[i].run(now, exec_ms);
                pred.record(pools[i].kind, 1, exec_ms);
                let lat = done - now;
                out.served_lat_ms.push(lat);
                if lat <= req.deadline_ms {
                    out.met += 1;
                } else {
                    out.missed += 1;
                    // Engine time burned on a reply the client gave up on.
                    out.wasted_ms += exec_ms;
                }
            }
        }
    }
    out
}

fn main() {
    let args = BenchArgs::from_env(20);
    let n = if args.quick { 25 } else { 100 };

    // Bursty camera trace: 5 frames every 500 ms (10 rps offered — above
    // either pool alone, below both together), deadline classes cycling
    // tight / mid / loose.
    let trace = Trace::generate(
        Pattern::Burst {
            size: 5,
            gap: Duration::from_millis(500),
        },
        n,
        42,
    );
    let mut rng = Rng::new(7);
    let reqs: Vec<Req> = trace
        .arrivals
        .iter()
        .enumerate()
        .map(|(i, at)| Req {
            at_ms: at.as_secs_f64() * 1e3,
            deadline_ms: match i % 3 {
                0 => 150.0,
                1 => 350.0,
                _ => 1000.0,
            },
            jitter: rng.uniform(0.97, 1.03),
        })
        .collect();

    println!("== E9: SLO attainment under bursts (n={n}, 5-per-500ms) ==");
    println!("| policy | attainment | met | missed | shed | p95 ms | wasted ms |");
    println!("|---|---|---|---|---|---|---|");
    let acl = run_sim(&reqs, Some(0));
    let quant = run_sim(&reqs, Some(1));
    let adaptive = run_sim(&reqs, None);
    println!("{}", acl.row("fixed-acl"));
    println!("{}", quant.row("fixed-quant"));
    println!("{}", adaptive.row("adaptive"));

    println!(
        "\nadaptive meets {} deadlines vs {} (fixed-quant) and {} (fixed-acl);",
        adaptive.met, quant.met, acl.met
    );
    println!(
        "sheds ({}) replace late executions, cutting wasted engine time to \
         {:.0} ms (vs {:.0} / {:.0}).",
        adaptive.shed, adaptive.wasted_ms, quant.wasted_ms, acl.wasted_ms
    );
    assert!(
        adaptive.met > acl.met && adaptive.met > quant.met,
        "adaptive ({}) must beat fixed-acl ({}) and fixed-quant ({})",
        adaptive.met,
        quant.met,
        acl.met
    );
    assert!(
        adaptive.wasted_ms <= quant.wasted_ms,
        "adaptive should not waste more engine time than fixed-quant"
    );

    // ---- real coordinator replay (needs artifacts) ----------------------
    let dir = zuluko::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("\nSKIP live-coordinator section: run `make artifacts` first");
        return;
    }
    run_live(args.quick);
}

/// Short live replay: one burst of deadline-tagged frames against the
/// adaptive coordinator, reporting attainment + policy counters.
fn run_live(quick: bool) {
    use zuluko::config::Config;
    use zuluko::coordinator::Coordinator;
    use zuluko::tensor::Tensor;

    let mut cfg = Config {
        engine: EngineKind::AclFused,
        workers: 1,
        max_batch: 4,
        batch_timeout: Duration::from_millis(20),
        queue_capacity: 16,
        ..Config::default()
    };
    cfg.policy.adaptive = true;
    cfg.policy.quant_workers = 1;
    cfg.policy.cache_capacity = 32;

    println!("\n== E9-live: adaptive coordinator, one deadline-tagged burst ==");
    let coord = match Coordinator::start(&cfg) {
        Ok(c) => c,
        Err(e) => {
            println!("SKIP live section (coordinator failed to start): {e:#}");
            return;
        }
    };
    let n = if quick { 6 } else { 12 };
    let mut receivers = Vec::new();
    let mut shed = 0usize;
    for i in 0..n {
        let slo = Slo::with_deadline_ms(match i % 3 {
            0 => 50.0, // tighter than any engine: shed at admission
            _ => 60_000.0,
        });
        match coord.submit_with_slo(Tensor::random(&[227, 227, 3], i as u64), slo) {
            Ok(rx) => receivers.push(rx),
            Err(_) => shed += 1,
        }
    }
    let mut ok = 0usize;
    for rx in receivers {
        if rx.recv().map(|r| r.is_ok()).unwrap_or(false) {
            ok += 1;
        }
    }
    let s = coord.stats();
    println!(
        "served={ok} shed={shed} cache={}h/{}m shed_predicted={}",
        s.cache_hits, s.cache_misses, s.shed_predicted
    );
    coord.shutdown();
}
