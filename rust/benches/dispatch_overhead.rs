//! E5 ablation: where does the framework tax come from?
//!
//! Sweeps execution granularity on identical compute: fully-fused (1
//! dispatch/img) -> staged (10) -> probe (15) -> op-by-op (66).  The
//! latency delta across the sweep isolates per-dispatch cost + lost
//! fusion, which is the mechanism behind the paper's Fig 3 gap.
//! Run: cargo bench --bench dispatch_overhead [-- --iters N | --quick]

use zuluko::bench::{Bench, BenchArgs, Stats};
use zuluko::engine::{build, EngineKind};
use zuluko::runtime::Manifest;
use zuluko::tensor::Tensor;

fn main() {
    let args = BenchArgs::from_env(10);
    let dir = zuluko::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP dispatch_overhead: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(&dir).expect("manifest");
    let input = Tensor::random(&[1, 227, 227, 3], 7);

    println!("== E5: dispatch-granularity ablation (iters={}) ==", args.iters);
    println!("| engine | dispatches/img | mean ms | ms/dispatch delta |");
    println!("|---|---|---|---|");

    let cases = [
        (EngineKind::AclFused, 1usize),
        (EngineKind::AclStaged, 10),
        (EngineKind::AclProbe, 15),
        (EngineKind::TfBaseline, 66),
    ];
    let mut base: Option<Stats> = None;
    let mut base_n = 1usize;
    for (kind, dispatches) in cases {
        let mut e = build(kind, &manifest).expect("engine");
        e.warmup().expect("warmup");
        let stats = Bench::new(kind.as_str())
            .warmup(args.warmup)
            .iters(args.iters)
            .run(|| {
                e.infer(&input).expect("infer");
            });
        let delta = match &base {
            None => 0.0,
            Some(b) => {
                (stats.mean_ms - b.mean_ms) / (dispatches - base_n).max(1) as f64
            }
        };
        println!(
            "| {} | {} | {:.1} | {:+.2} |",
            kind.as_str(),
            dispatches,
            stats.mean_ms,
            delta
        );
        if base.is_none() {
            base = Some(stats);
            base_n = dispatches;
        }
    }
    println!("\nshape check: latency must rise monotonically with dispatch count");
    println!("(fused < staged < probe < op-by-op) — the framework-overhead mechanism.");
}
