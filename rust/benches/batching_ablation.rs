//! E8 ablation: dynamic-batching policy sweep (serving extension).
//!
//! Direct engine-level sweep of the compiled batch variants (amortizing
//! dispatch + weight traffic across images), then a coordinator-level
//! sweep of the batch window under burst load.
//! Run: cargo bench --bench batching_ablation [-- --iters N | --quick]

use std::sync::Arc;
use std::time::Duration;

use zuluko::bench::{Bench, BenchArgs};
use zuluko::config::Config;
use zuluko::coordinator::Coordinator;
use zuluko::engine::{build, EngineKind};
use zuluko::runtime::Manifest;
use zuluko::tensor::Tensor;

fn main() {
    let args = BenchArgs::from_env(8);
    let dir = zuluko::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP batching_ablation: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(&dir).expect("manifest");

    // ---- engine-level: batch-size scaling of the fused artifacts ----
    println!("== E8a: batch-size scaling, acl-fused (iters={}) ==", args.iters);
    println!("| batch | mean ms/batch | ms/image | images/s |");
    println!("|---|---|---|---|");
    let mut e = build(EngineKind::AclFused, &manifest).expect("engine");
    e.warmup().expect("warmup");
    let batches: Vec<usize> = manifest.full.keys().copied().collect();
    for b in batches {
        let batch = Tensor::random(&[b, 227, 227, 3], b as u64);
        let stats = Bench::new(&format!("b{b}"))
            .warmup(1)
            .iters(args.iters)
            .run(|| {
                e.infer(&batch).expect("infer");
            });
        println!(
            "| {} | {:.1} | {:.1} | {:.2} |",
            b,
            stats.mean_ms,
            stats.mean_ms / b as f64,
            b as f64 / stats.mean_ms * 1e3
        );
    }

    // ---- coordinator-level: batch window sweep under a burst ----
    println!("\n== E8b: batch-window sweep under 8-request bursts ==");
    println!("| window ms | mean batch | p50 ms | p95 ms | throughput img/s |");
    println!("|---|---|---|---|---|");
    for window_ms in [0u64, 10, 40, 120] {
        let cfg = Config {
            engine: EngineKind::AclFused,
            workers: 1,
            max_batch: 8,
            batch_timeout: Duration::from_millis(window_ms),
            queue_capacity: 64,
            ..Config::default()
        };
        let coord = Arc::new(Coordinator::start(&cfg).expect("coordinator"));
        let n = if args.quick { 8 } else { 24 };
        let t0 = std::time::Instant::now();
        let mut rxs = Vec::new();
        for i in 0..n {
            let img = Tensor::random(&[227, 227, 3], i as u64);
            rxs.push(coord.submit(img).expect("submit"));
        }
        for rx in rxs {
            let r = rx.recv().expect("response");
            assert!(r.is_ok(), "{:?}", r.error);
        }
        let wall = t0.elapsed().as_secs_f64();
        let s = coord.stats();
        let (_, p50, p95, _, _) = s.latency_summary;
        println!(
            "| {} | {:.2} | {:.0} | {:.0} | {:.2} |",
            window_ms,
            s.mean_batch,
            p50,
            p95,
            n as f64 / wall
        );
        match Arc::try_unwrap(coord) {
            Ok(c) => {
                c.shutdown();
            }
            Err(_) => panic!("coordinator still referenced"),
        }
    }
    println!("\nshape check: larger windows -> bigger batches -> higher throughput,");
    println!("at the cost of added queueing latency (the classic batching tradeoff).");
}
