//! E3 / Figure 3 panel 3: CPU utilization and memory footprint.
//!
//! Paper: TF averaged 75% CPU and ~9 MB; the ACL engine 90% CPU and
//! ~10 MB — the from-scratch engine keeps the core busier (thin dispatch)
//! at a slightly larger footprint.  Absolute RSS here includes the XLA
//! runtime; the claim under test is the *ordering*.
//! Run: cargo bench --bench fig3_utilization [-- --iters N | --quick]

use std::time::Duration;

use zuluko::bench::BenchArgs;
use zuluko::engine::{build, EngineKind};
use zuluko::metrics::sysmon::Sysmon;
use zuluko::runtime::Manifest;
use zuluko::tensor::Tensor;

fn main() {
    let args = BenchArgs::from_env(8);
    let dir = zuluko::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP fig3_utilization: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(&dir).expect("manifest");
    let input = Tensor::random(&[1, 227, 227, 3], 7);

    println!("== E3 / Fig 3: utilization (iters={}) ==", args.iters);
    println!("| engine | cpu % | rss avg MB | rss peak MB | registry peak MB | paper |");
    println!("|---|---|---|---|---|---|");

    for (kind, paper) in [
        (EngineKind::TfBaseline, "75% / ~9 MB"),
        (EngineKind::AclStaged, "90% / ~10 MB"),
    ] {
        let mut e = build(kind, &manifest).expect("engine");
        e.warmup().expect("warmup");
        let mon = Sysmon::start(Duration::from_millis(50));
        for _ in 0..args.iters {
            e.infer(&input).expect("infer");
        }
        let u = mon.stop().expect("sysmon");
        // Framework tensor-registry footprint (tf engine only).
        let registry_mb = if kind == EngineKind::TfBaseline {
            // Re-run one image through the tf engine to read its stats.
            let mut tf = zuluko::engine::tf::TfBaselineEngine::new(&manifest).unwrap();
            use zuluko::engine::Engine;
            tf.infer(&input).unwrap();
            tf.last_stats.peak_registry_bytes as f64 / 1e6
        } else {
            0.0
        };
        println!(
            "| {} | {:.0}% | {:.0} | {:.0} | {:.1} | {} |",
            kind.as_str(),
            u.cpu_frac * 100.0,
            u.avg_rss_mb,
            u.peak_rss_mb,
            registry_mb,
            paper
        );
    }
    println!("\nnote: single-core substrate; paper had 4 ARM cores. CPU% is of one core.");
}
