//! E1 / Figure 3 panel 1: end-to-end per-image latency, TF-baseline vs
//! the from-scratch ACL engine (staged + fully-fused).
//!
//! Paper shape: ACL beats TF by ~25% (420 ms -> 320 ms on 4xARMv7).
//! Run: cargo bench --bench fig3_engines [-- --iters N | --quick]

use zuluko::bench::{speedup_line, Bench, BenchArgs, Stats};
use zuluko::engine::{build, EngineKind};
use zuluko::runtime::Manifest;
use zuluko::tensor::Tensor;

fn main() {
    let args = BenchArgs::from_env(15);
    let dir = zuluko::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP fig3_engines: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(&dir).expect("manifest");
    let input = Tensor::random(&[1, 227, 227, 3], 7);

    println!("== E1 / Fig 3: engine end-to-end latency (iters={}) ==", args.iters);
    println!("{}", Stats::HEADER);

    let mut results = Vec::new();
    for kind in [
        EngineKind::TfBaseline,
        EngineKind::AclStaged,
        EngineKind::AclFused,
    ] {
        let mut e = build(kind, &manifest).expect("engine");
        e.warmup().expect("warmup");
        let stats = Bench::new(kind.as_str())
            .warmup(args.warmup)
            .iters(args.iters)
            .run(|| {
                e.infer(&input).expect("infer");
            });
        println!("{}", stats.row());
        results.push(stats);
    }

    println!();
    println!("{}", speedup_line(&results[0], &results[1]));
    println!("{}", speedup_line(&results[0], &results[2]));
    println!("paper: 420 ms -> 320 ms = 1.31x (ACL wins by ~25%)");
}
