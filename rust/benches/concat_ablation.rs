//! E6 ablation: the fire-module concat elimination (paper Figure 1).
//!
//! The paper's engine writes expand branches into channel slices of a
//! shared buffer, deleting the concatenate op entirely.  This bench
//! quantifies what that deletion is worth: the measured cost of the 8
//! concat copies in the baseline graph, and the bytes they move.
//! Run: cargo bench --bench concat_ablation [-- --iters N | --quick]

use zuluko::bench::BenchArgs;
use zuluko::engine::{build, EngineKind};
use zuluko::runtime::Manifest;
use zuluko::tensor::Tensor;

fn main() {
    let args = BenchArgs::from_env(10);
    let dir = zuluko::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP concat_ablation: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(&dir).expect("manifest");
    let input = Tensor::random(&[1, 227, 227, 3], 7);

    let mut tf = build(EngineKind::TfBaseline, &manifest).expect("tf");
    tf.warmup().expect("warmup");
    tf.ledger_mut().clear();
    for _ in 0..args.iters {
        tf.infer(&input).expect("infer");
    }
    let n = args.iters as f64;

    println!("== E6: concat-elimination ablation (iters={}) ==", args.iters);
    println!("| fire concat | bytes moved/img | ms/img |");
    println!("|---|---|---|");
    let mut total_ms = 0.0;
    let mut total_bytes = 0usize;
    for op in &manifest.ops {
        if op.kind != "concat" {
            continue;
        }
        let bytes: usize = op.out_shape.iter().product::<usize>() * 4;
        let ms = tf
            .ledger()
            .rows()
            .iter()
            .find(|(name, ..)| name == &op.name)
            .map(|(_, _, _, ms)| ms / n)
            .unwrap_or(0.0);
        println!("| {} | {} | {:.2} |", op.name, bytes, ms);
        total_ms += ms;
        total_bytes += bytes;
    }
    println!("| TOTAL | {} ({:.1} MB) | {:.2} |", total_bytes,
             total_bytes as f64 / 1e6, total_ms);

    let e2e: f64 = tf.ledger().total().as_secs_f64() * 1e3 / n;
    println!(
        "\nconcat share of baseline compute: {:.1}% ({:.2} of {:.1} ms) — \
         the ACL engine pays 0 (fused fire kernel writes channel slices)",
        total_ms / e2e * 100.0,
        total_ms,
        e2e
    );
}
