//! E2 / Figure 3 panel 2: the group 1 / group 2 time breakdown.
//!
//! group 1 = convolution + ReLU + concatenate; group 2 = pooling +
//! soft-max.  Paper shape: ACL wins group 1 by ~23% and group 2 by ~110%
//! (small ops suffer most from framework dispatch).
//! Run: cargo bench --bench fig3_breakdown [-- --iters N | --quick]

use zuluko::bench::BenchArgs;
use zuluko::engine::{build, Engine, EngineKind};
use zuluko::metrics::ledger::Group;
use zuluko::runtime::Manifest;
use zuluko::tensor::Tensor;

fn groups_per_image(e: &mut Box<dyn Engine>, input: &Tensor, iters: usize) -> [f64; 4] {
    e.ledger_mut().clear();
    for _ in 0..iters {
        e.infer(input).expect("infer");
    }
    let g = e.ledger().group_ms();
    [
        g[0] / iters as f64,
        g[1] / iters as f64,
        g[2] / iters as f64,
        g[3] / iters as f64,
    ]
}

fn main() {
    let args = BenchArgs::from_env(10);
    let dir = zuluko::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP fig3_breakdown: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(&dir).expect("manifest");
    let input = Tensor::random(&[1, 227, 227, 3], 7);

    println!("== E2 / Fig 3: group breakdown (iters={}) ==", args.iters);

    let mut tf = build(EngineKind::TfBaseline, &manifest).expect("tf");
    tf.warmup().expect("warmup");
    let tfg = groups_per_image(&mut tf, &input, args.iters);

    let mut acl = build(EngineKind::AclProbe, &manifest).expect("acl-probe");
    acl.warmup().expect("warmup");
    let aclg = groups_per_image(&mut acl, &input, args.iters);

    println!("| group | tf ms/img | acl ms/img | acl speedup | paper |");
    println!("|---|---|---|---|---|");
    println!(
        "| {} | {:.1} | {:.1} | {:.2}x | 1.23x |",
        Group::Group1.name(),
        tfg[0],
        aclg[0],
        tfg[0] / aclg[0].max(1e-9)
    );
    println!(
        "| {} | {:.1} | {:.1} | {:.2}x | 2.10x |",
        Group::Group2.name(),
        tfg[1],
        aclg[1],
        tfg[1] / aclg[1].max(1e-9)
    );

    // Per-op detail for the appendix: top-8 most expensive tf ops.
    let mut rows = tf.ledger().rows();
    rows.sort_by(|a, b| b.3.partial_cmp(&a.3).unwrap());
    println!("\ntop tf ops by total ms (ledger):");
    for (name, group, calls, ms) in rows.iter().take(8) {
        println!("  {:<22} {:<26} calls={:<4} {:>8.1} ms", name, group.name(), calls, ms);
    }
}
