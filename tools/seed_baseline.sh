#!/usr/bin/env sh
# Seed tools/bench_baseline.json from a CI bench artifact.
#
# The perf-regression gate (tools/bench_gate.rs, `make bench-gate`)
# compares each run's trajectory against the committed baseline.  The
# baseline must come from a CI runner measurement — never hand-write
# numbers, and never commit one measured on a noisy dev laptop, or the
# gate compares apples to oranges and either flaps or goes blind.
#
# Usage:
#   tools/seed_baseline.sh <run-id>   # pull the bench-baseline-seed
#                                     # artifact from that CI run
#   tools/seed_baseline.sh            # latest run on the current branch
#   tools/seed_baseline.sh --from-file <path>
#                                     # seed from a local trajectory
#                                     # file (e.g. a bench-trajectory
#                                     # artifact already downloaded);
#                                     # schema-checked, never hand-write
#
# Requires the GitHub CLI (`gh`) authenticated against the repo, except
# in --from-file mode.  After running, review the diff and commit
# tools/bench_baseline.json.

set -eu

cd "$(dirname "$0")/.."

# Refuse anything that is not a hot_path_alloc trajectory with the
# "pooled" mode row the gate keys on — catches seeding from the wrong
# artifact (or a hand-written file) before the gate goes blind.
check_schema() {
    if ! grep -q '"bench":"hot_path_alloc"' "$1" \
        || ! grep -q '"name":"pooled"' "$1"; then
        echo "error: $1 is not a hot_path_alloc trajectory (missing" \
            "\"bench\":\"hot_path_alloc\" or the \"pooled\" mode row)" >&2
        exit 1
    fi
}

if [ "${1:-}" = "--from-file" ]; then
    SEED="${2:?usage: tools/seed_baseline.sh --from-file <path>}"
    if [ ! -f "$SEED" ]; then
        echo "error: no such file: $SEED" >&2
        exit 1
    fi
    check_schema "$SEED"
    cp "$SEED" tools/bench_baseline.json
    echo "wrote tools/bench_baseline.json from $SEED:"
    head -n 5 tools/bench_baseline.json
    echo "... review and commit it to make the gate enforcing across PRs."
    exit 0
fi

if ! command -v gh >/dev/null 2>&1; then
    echo "error: this helper needs the GitHub CLI (gh)" >&2
    exit 1
fi

RUN_ID="${1:-}"
if [ -z "$RUN_ID" ]; then
    BRANCH="$(git rev-parse --abbrev-ref HEAD)"
    RUN_ID="$(gh run list --workflow ci --branch "$BRANCH" \
        --status success --limit 1 --json databaseId \
        --jq '.[0].databaseId' || true)"
    if [ -z "$RUN_ID" ] || [ "$RUN_ID" = "null" ]; then
        echo "error: no successful ci run found on branch '$BRANCH'" >&2
        exit 1
    fi
fi

echo "downloading bench-baseline-seed from run $RUN_ID ..."
TMP="$(mktemp -d)"
trap 'rm -rf "$TMP"' EXIT
gh run download "$RUN_ID" --name bench-baseline-seed --dir "$TMP"

# The artifact contains bench_baseline.json (see .github/workflows/ci.yml).
SEED="$(find "$TMP" -name '*.json' | head -n 1)"
if [ -z "$SEED" ]; then
    echo "error: artifact from run $RUN_ID holds no json" >&2
    exit 1
fi
cp "$SEED" tools/bench_baseline.json
echo "wrote tools/bench_baseline.json from CI run $RUN_ID:"
head -n 5 tools/bench_baseline.json
echo "... review and commit it to make the gate enforcing across PRs."
