//! CI perf-regression gate over the bench trajectory JSON.
//!
//! Compares a current `make bench-json` output (the $(GATE_OUT) file,
//! written by rust/benches/hot_path_alloc.rs) against a committed
//! baseline and fails the job when the shipped serving path regresses:
//!
//! * `allocs_per_req` (deterministic counting-allocator events) may not
//!   grow more than the threshold (default 20%) — plus a small absolute
//!   slack so a 0.10 -> 0.13 jitter on a near-zero baseline is not a
//!   "30% regression";
//! * `p99_ms` may not grow more than the threshold *and* more than an
//!   absolute floor (timing percentiles are noisy on shared CI runners;
//!   a 0.02ms -> 0.03ms wobble is not a regression).
//!
//! Usage:
//!   bench_gate <baseline.json> <current.json> [--max-regress 0.20]
//!              [--require-baseline]
//!
//! A missing baseline passes with a notice (first run of a fresh
//! trajectory) unless `--require-baseline` is given.  Exit code 1 on any
//! violation, with one explanatory line per violation.
//!
//! Seed/refresh the baseline with `make bench-baseline` on a quiet
//! machine, then commit `tools/bench_baseline.json`.

use std::path::Path;
use std::process::ExitCode;

use zuluko::util::json::Json;

/// Gate thresholds.
#[derive(Debug, Clone, Copy)]
pub struct GateOpts {
    /// Max allowed relative growth (0.20 = +20%).
    pub max_regress: f64,
    /// Absolute slack for alloc events/request (counting jitter).
    pub alloc_abs_slack: f64,
    /// Absolute floor below which p99 growth is considered noise, ms.
    pub p99_abs_floor_ms: f64,
}

impl Default for GateOpts {
    fn default() -> GateOpts {
        GateOpts {
            max_regress: 0.20,
            alloc_abs_slack: 0.5,
            p99_abs_floor_ms: 0.2,
        }
    }
}

/// One metric row pulled from a bench JSON's `modes` array.
#[derive(Debug, Clone)]
struct Mode {
    allocs_per_req: f64,
    p99_ms: f64,
}

fn mode(doc: &Json, name: &str) -> Option<Mode> {
    let modes = doc.get("modes")?.as_arr()?;
    let m = modes
        .iter()
        .find(|m| m.get("name").and_then(|n| n.as_str()) == Some(name))?;
    Some(Mode {
        allocs_per_req: m.get("allocs_per_req")?.as_f64()?,
        p99_ms: m.get("p99_ms")?.as_f64()?,
    })
}

/// Compare baseline vs current; returns human-readable violations
/// (empty = gate passes).  Pure so the gate itself is unit-testable —
/// the acceptance check "fails on an injected >20% regression" lives in
/// the tests below.
pub fn gate(baseline: &Json, current: &Json, opts: GateOpts) -> Vec<String> {
    let mut violations = Vec::new();
    // The shipped serving path is the pooled mode; that is the one the
    // gate protects.  (unpooled/legacy are ablation references.)
    let (base, cur) = match (mode(baseline, "pooled"), mode(current, "pooled")) {
        (Some(b), Some(c)) => (b, c),
        (b, c) => {
            violations.push(format!(
                "missing 'pooled' mode row (baseline: {}, current: {})",
                if b.is_some() { "ok" } else { "absent" },
                if c.is_some() { "ok" } else { "absent" },
            ));
            return violations;
        }
    };

    let alloc_limit =
        base.allocs_per_req * (1.0 + opts.max_regress) + opts.alloc_abs_slack;
    if cur.allocs_per_req > alloc_limit {
        violations.push(format!(
            "allocs/request regressed: {:.2} -> {:.2} (limit {:.2} = \
             baseline +{:.0}% +{:.1} slack)",
            base.allocs_per_req,
            cur.allocs_per_req,
            alloc_limit,
            opts.max_regress * 100.0,
            opts.alloc_abs_slack,
        ));
    }

    let p99_rel_limit = base.p99_ms * (1.0 + opts.max_regress);
    if cur.p99_ms > p99_rel_limit && cur.p99_ms - base.p99_ms > opts.p99_abs_floor_ms {
        violations.push(format!(
            "p99 latency regressed: {:.3}ms -> {:.3}ms (limit {:.3}ms = \
             baseline +{:.0}%, noise floor {:.1}ms)",
            base.p99_ms,
            cur.p99_ms,
            p99_rel_limit,
            opts.max_regress * 100.0,
            opts.p99_abs_floor_ms,
        ));
    }

    violations
}

/// On GitHub Actions, surface a missing-baseline (self-seeded,
/// regression-blind) run as a `::warning::` annotation and a line in
/// the job summary.  Off CI both are harmless no-ops: the annotation is
/// one extra stdout line and GITHUB_STEP_SUMMARY is unset.
fn annotate_missing_baseline(baseline_path: &str) {
    let msg = format!(
        "bench_gate ran without a committed baseline ({baseline_path}): this \
         run is regression-blind. Seed with `make bench-baseline` and commit \
         tools/bench_baseline.json to arm the perf gate."
    );
    println!("::warning::{msg}");
    if let Ok(summary) = std::env::var("GITHUB_STEP_SUMMARY") {
        use std::io::Write;
        if let Ok(mut f) = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(summary)
        {
            let _ = writeln!(f, ":warning: {msg}");
        }
    }
}

fn load(path: &str) -> Result<Json, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("reading {path}: {e}"))?;
    Json::parse(&text).map_err(|e| format!("parsing {path}: {e}"))
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut positional: Vec<String> = Vec::new();
    let mut opts = GateOpts::default();
    let mut require_baseline = false;
    let mut i = 0usize;
    while i < argv.len() {
        match argv[i].as_str() {
            "--max-regress" => {
                match argv.get(i + 1).and_then(|v| v.parse::<f64>().ok()) {
                    Some(v) if v > 0.0 => opts.max_regress = v,
                    _ => {
                        eprintln!("--max-regress expects a positive number");
                        return ExitCode::FAILURE;
                    }
                }
                i += 2;
            }
            "--require-baseline" => {
                require_baseline = true;
                i += 1;
            }
            flag if flag.starts_with("--") => {
                eprintln!("bench_gate: unknown flag {flag}");
                return ExitCode::FAILURE;
            }
            _ => {
                positional.push(argv[i].clone());
                i += 1;
            }
        }
    }
    let (baseline_path, current_path) = match positional.as_slice() {
        [b, c] => (b.as_str(), c.as_str()),
        _ => {
            eprintln!(
                "usage: bench_gate <baseline.json> <current.json> \
                 [--max-regress 0.20] [--require-baseline]"
            );
            return ExitCode::FAILURE;
        }
    };

    if !Path::new(baseline_path).exists() {
        if require_baseline {
            eprintln!("bench_gate: baseline {baseline_path} missing (required)");
            return ExitCode::FAILURE;
        }
        println!(
            "bench_gate: no baseline at {baseline_path} — gate passes with a \
             notice.  Seed one with `make bench-baseline` and commit it to \
             arm the gate."
        );
        // Make the regression-blind pass loud on CI: a workflow
        // annotation plus a job-summary line, so a missing committed
        // baseline never reads as a genuinely green perf gate.
        annotate_missing_baseline(baseline_path);
        return ExitCode::SUCCESS;
    }

    let (baseline, current) = match (load(baseline_path), load(current_path)) {
        (Ok(b), Ok(c)) => (b, c),
        (b, c) => {
            for e in [b.err(), c.err()].into_iter().flatten() {
                eprintln!("bench_gate: {e}");
            }
            return ExitCode::FAILURE;
        }
    };

    let violations = gate(&baseline, &current, opts);
    if violations.is_empty() {
        println!(
            "bench_gate: OK — pooled path within {:.0}% of baseline \
             ({baseline_path} vs {current_path})",
            opts.max_regress * 100.0
        );
        ExitCode::SUCCESS
    } else {
        for v in &violations {
            eprintln!("bench_gate: FAIL — {v}");
        }
        ExitCode::FAILURE
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc(allocs: f64, p99: f64) -> Json {
        let text = format!(
            r#"{{"bench":"hot_path_alloc","modes":[
                {{"name":"pooled","allocs_per_req":{allocs},
                  "bytes_per_req":100.0,"throughput_rps":1000.0,
                  "p50_ms":1.0,"p99_ms":{p99}}},
                {{"name":"unpooled","allocs_per_req":9.0,
                  "bytes_per_req":3000000.0,"throughput_rps":900.0,
                  "p50_ms":1.2,"p99_ms":2.0}}]}}"#
        );
        Json::parse(&text).unwrap()
    }

    #[test]
    fn passes_when_within_threshold() {
        let base = doc(5.0, 10.0);
        let cur = doc(5.5, 11.0); // +10%
        assert!(gate(&base, &cur, GateOpts::default()).is_empty());
    }

    #[test]
    fn fails_on_injected_alloc_regression_over_20pct() {
        let base = doc(5.0, 10.0);
        let cur = doc(7.0, 10.0); // +40% allocs/request
        let v = gate(&base, &cur, GateOpts::default());
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].contains("allocs/request"), "{v:?}");
    }

    #[test]
    fn fails_on_p99_regression_over_20pct() {
        let base = doc(5.0, 10.0);
        let cur = doc(5.0, 13.0); // +30% and > noise floor
        let v = gate(&base, &cur, GateOpts::default());
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].contains("p99"), "{v:?}");
    }

    #[test]
    fn tiny_absolute_wobbles_are_not_regressions() {
        // Near-zero baselines: +30% relative but microscopic absolute.
        let base = doc(0.1, 0.02);
        let cur = doc(0.13, 0.03);
        assert!(gate(&base, &cur, GateOpts::default()).is_empty());
    }

    #[test]
    fn missing_pooled_mode_is_a_violation() {
        let base = doc(5.0, 10.0);
        let empty = Json::parse(r#"{"modes":[]}"#).unwrap();
        let v = gate(&base, &empty, GateOpts::default());
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("missing 'pooled'"));
    }

    #[test]
    fn improvements_always_pass() {
        let base = doc(5.0, 10.0);
        let cur = doc(1.0, 2.0);
        assert!(gate(&base, &cur, GateOpts::default()).is_empty());
    }
}
