"""Shared tiling / padding helpers for the Pallas kernel library.

Hardware-adaptation note (DESIGN.md §Hardware-Adaptation): the paper's ACL
operators are NEON-intrinsic loops streaming rows through 128-bit vector
registers.  The TPU-shaped equivalent is: tile the output height, stream the
halo'd input rows HBM→VMEM per grid step, and shape the inner loop as an
`(M, K) x (K, N)` matmul for the MXU.  All kernels here follow that scheme;
`vmem_bytes_*` helpers compute the per-step footprint so DESIGN.md §Perf can
check it against the 16 MiB VMEM budget.
"""

from __future__ import annotations

import jax.numpy as jnp

# TPU-v4-ish VMEM budget we tile against (bytes).
VMEM_BUDGET = 16 * 1024 * 1024

# MXU native tile (rows x cols for f32/bf16 operands).
MXU_TILE = 128


def ceil_div(a: int, b: int) -> int:
    """Ceiling division (python ints)."""
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    """Round `a` up to a multiple of `b`."""
    return ceil_div(a, b) * b


def conv_out_dim(size: int, k: int, stride: int, pad: int) -> int:
    """Output spatial size of a conv/pool with symmetric padding `pad`."""
    return (size + 2 * pad - k) // stride + 1


def resolve_padding(padding: str | int, k: int) -> tuple[int, int]:
    """Normalize a padding spec to (lo, hi) pad counts.

    "SAME" here means the SqueezeNet usage: stride-1 SAME for odd k, i.e.
    symmetric (k-1)//2 / k-1-(k-1)//2.
    """
    if isinstance(padding, int):
        return padding, padding
    if padding == "VALID":
        return 0, 0
    if padding == "SAME":
        p = (k - 1) // 2
        return p, k - 1 - p
    raise ValueError(f"bad padding {padding!r}")


def pick_row_tile(h_out: int, w_out: int, cout: int, target_rows: int = 8) -> int:
    """Pick the output-row tile height TH.

    Heuristic: `target_rows` rows per grid step unless the output is small,
    in which case take it whole.  TH only shapes the HBM→VMEM schedule; it
    never affects numerics (tests sweep TH explicitly to prove that).
    """
    del w_out, cout  # shape-only heuristic today; kept for tuning hooks
    return min(target_rows, h_out) if h_out > 0 else 1


def vmem_bytes_conv(
    th: int, w_in: int, cin: int, k: int, stride: int, w_out: int, cout: int,
    dtype_bytes: int = 4,
) -> int:
    """Per-grid-step VMEM footprint of the conv kernel.

    input tile rows + full weights + bias + output tile + accumulator.
    """
    rows_in = (th - 1) * stride + k
    x_tile = rows_in * w_in * cin
    w_full = k * k * cin * cout
    out_tile = th * w_out * cout
    return (x_tile + w_full + cout + 2 * out_tile) * dtype_bytes


def pad_rows_for_tiles(h_in: int, n_tiles: int, th: int, stride: int, k: int) -> int:
    """Input rows needed so every grid step can load a full halo'd tile.

    The last (ragged) output tile still issues a full-height load; we
    zero-pad the input so that load stays in bounds.  Zero rows only feed
    output rows that the ragged write drops, so numerics are unaffected.
    """
    need = (n_tiles - 1) * th * stride + (th - 1) * stride + k
    return max(0, need - h_in)


def masked_rows(jnp_mod, rows: int, valid_lo: int, valid_hi: int):
    """Row-validity mask of shape (rows, 1, 1): valid_lo <= r < valid_hi."""
    r = jnp_mod.arange(rows).reshape(rows, 1, 1)
    return (r >= valid_lo) & (r < valid_hi)


def assert_nhwc(x: jnp.ndarray, name: str = "x") -> None:
    """Guard: kernels are NHWC-only."""
    if x.ndim != 4:
        raise ValueError(f"{name} must be NHWC (4-D), got shape {x.shape}")
