"""Pallas convolution kernels (the "ACL Convolution" building block).

Two kernels:

* `conv2d` — generic KxK strided conv as a *shifted matmul*: the output
  tile is accumulated as `sum_{di,dj} X[di::s, dj::s, :] @ W[di, dj]`.
  Each of the KxK partial products is an `(TH*W_out, Cin) x (Cin, Cout)`
  matmul, which is exactly the MXU-shaped inner loop the paper's NEON
  GEMM-based conv uses (im2col without materializing the im2col buffer).

* `pointwise_conv` — the 1x1 special case as a flat row-tiled matmul.
  SqueezeNet is dominated by 1x1 convs (squeeze + expand1x1 + conv10), so
  this path matters most; it skips halo logic entirely.

Grid/BlockSpec scheme (see common.py): grid = (N, ceil(H_out/TH)); the
input block is the whole (padded) image for that batch element and the
kernel slices its halo'd row window with `pl.dynamic_slice` — this models
the HBM→VMEM row-streaming schedule; the output block is the (1, TH,
W_out, Cout) tile.

All kernels run `interpret=True` (CPU PJRT cannot execute Mosaic
custom-calls); correctness vs `ref.py` is the contract, and §Perf reasons
about VMEM/MXU structure instead of interpret-mode wallclock.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


# ---------------------------------------------------------------------------
# Generic KxK conv
# ---------------------------------------------------------------------------

def _conv2d_kernel(x_ref, w_ref, b_ref, o_ref, *, th, stride, k, w_out,
                   activation):
    """One grid step: compute a (TH, W_out, Cout) output tile."""
    h = pl.program_id(1)
    row0 = h * th * stride
    rows_in = (th - 1) * stride + k

    # Halo'd input rows for this tile (modelled VMEM load).
    x_tile = pl.load(
        x_ref,
        (0, pl.dslice(row0, rows_in), slice(None), slice(None)),
    )  # (rows_in, W_pad, Cin)

    cin = x_tile.shape[-1]
    cout = o_ref.shape[-1]
    acc = jnp.zeros((th * w_out, cout), dtype=jnp.float32)
    # KxK shifted matmuls, statically unrolled.
    for di in range(k):
        for dj in range(k):
            patch = jax.lax.slice(
                x_tile,
                (di, dj, 0),
                (di + (th - 1) * stride + 1,
                 dj + (w_out - 1) * stride + 1,
                 cin),
                (stride, stride, 1),
            )  # (TH, W_out, Cin)
            acc = acc + jnp.dot(
                patch.reshape(th * w_out, cin),
                w_ref[di, dj],
                preferred_element_type=jnp.float32,
            )

    out = acc.reshape(th, w_out, cout) + b_ref[...]
    if activation == "relu":
        out = jnp.maximum(out, 0.0)
    o_ref[0] = out.astype(o_ref.dtype)


def conv2d(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array | None = None,
    *,
    stride: int = 1,
    padding: str | int = "VALID",
    activation: str | None = None,
    row_tile: int | None = None,
) -> jax.Array:
    """KxK conv, NHWC x (K,K,Cin,Cout) [+bias] [+relu] -> NHWC.

    `row_tile` overrides the output-row tile height TH (tests sweep it to
    prove tiling never changes numerics).
    """
    common.assert_nhwc(x)
    n, h_in, w_in, cin = x.shape
    k, k2, wcin, cout = w.shape
    assert k == k2 and wcin == cin, (w.shape, x.shape)
    if b is None:
        b = jnp.zeros((cout,), dtype=x.dtype)

    plo, phi = common.resolve_padding(padding, k)
    h_out = common.conv_out_dim(h_in, k, stride, 0) if (plo, phi) == (0, 0) \
        else (h_in + plo + phi - k) // stride + 1
    w_out = (w_in + plo + phi - k) // stride + 1
    if h_out <= 0 or w_out <= 0:
        raise ValueError(f"conv output empty: in={x.shape} k={k} s={stride}")

    th = row_tile or common.pick_row_tile(h_out, w_out, cout)
    th = min(th, h_out)
    n_tiles = common.ceil_div(h_out, th)

    # Spatial padding + bottom tile-safety padding (zeros feed only rows the
    # ragged output tile drops — see common.pad_rows_for_tiles).
    extra = common.pad_rows_for_tiles(h_in + plo + phi, n_tiles, th, stride, k)
    xp = jnp.pad(x, ((0, 0), (plo, phi + extra), (plo, phi), (0, 0)))
    h_pad, w_pad = xp.shape[1], xp.shape[2]

    kern = functools.partial(
        _conv2d_kernel, th=th, stride=stride, k=k, w_out=w_out,
        activation=activation,
    )
    return pl.pallas_call(
        kern,
        grid=(n, n_tiles),
        in_specs=[
            pl.BlockSpec((1, h_pad, w_pad, cin), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((k, k, cin, cout), lambda i, j: (0, 0, 0, 0)),
            pl.BlockSpec((cout,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((1, th, w_out, cout), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h_out, w_out, cout), x.dtype),
        interpret=True,
    )(xp, w, b)


# ---------------------------------------------------------------------------
# 1x1 conv (flat matmul)
# ---------------------------------------------------------------------------

def _pointwise_kernel(x_ref, w_ref, b_ref, o_ref, *, activation):
    """One grid step: (TM, Cin) x (Cin, Cout) tile matmul."""
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    out = acc + b_ref[...]
    if activation == "relu":
        out = jnp.maximum(out, 0.0)
    o_ref[...] = out.astype(o_ref.dtype)


def pointwise_conv(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array | None = None,
    *,
    activation: str | None = None,
    row_tile: int | None = None,
) -> jax.Array:
    """1x1 conv as a row-tiled matmul over the flattened spatial axes.

    VMEM per step: TM*Cin + Cin*Cout + TM*Cout floats; TM defaults to the
    largest multiple of the MXU tile that fits the budget.
    """
    common.assert_nhwc(x)
    if w.ndim == 4:
        assert w.shape[:2] == (1, 1), w.shape
        w = w[0, 0]
    cin, cout = w.shape
    n, h, ww, xc = x.shape
    assert xc == cin, (x.shape, w.shape)
    if b is None:
        b = jnp.zeros((cout,), dtype=x.dtype)

    m = n * h * ww
    xm = x.reshape(m, cin)
    tm = row_tile or min(m, common.round_up(
        max(1, common.VMEM_BUDGET // (4 * max(1, (cin + cout)) * 4)),
        common.MXU_TILE))
    tm = min(tm, m)
    n_tiles = common.ceil_div(m, tm)

    out = pl.pallas_call(
        functools.partial(_pointwise_kernel, activation=activation),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((tm, cin), lambda i: (i, 0)),
            pl.BlockSpec((cin, cout), lambda i: (0, 0)),
            pl.BlockSpec((cout,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tm, cout), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, cout), x.dtype),
        interpret=True,
    )(xm, w, b)
    return out.reshape(n, h, ww, cout)
