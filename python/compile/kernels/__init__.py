"""L1 Pallas kernel library — the "ARM Compute Library" of this repro.

Every operator the paper's engine uses, as a Pallas kernel with an exact
pure-jnp oracle in `ref.py`:

- conv:       `conv2d`, `pointwise_conv`
- activation: `relu`, `softmax`, `concat_channels` (baseline-only)
- pool:       `maxpool2d`, `global_avgpool` (w/ dropout attenuation)
- fire:       `fire` (fused, concat-free — the paper's key trick)
- quant:      `quantize`, `dequantize`, `conv2d_q8` (Fig 4 substrate)
"""

from .activation import concat_channels, relu, scale_mul, softmax
from .conv import conv2d, pointwise_conv
from .fire import fire
from .pool import global_avgpool, maxpool2d
from .quant import conv2d_q8, dequant_bias, dequantize, quantize

__all__ = [
    "concat_channels", "relu", "scale_mul", "softmax",
    "conv2d", "pointwise_conv",
    "fire",
    "global_avgpool", "maxpool2d",
    "conv2d_q8", "dequant_bias", "dequantize", "quantize",
]
