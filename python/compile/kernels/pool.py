"""Pallas pooling kernels ("ACL Pooling" + the paper's hand-rolled ops).

* `maxpool2d` — VALID KxK/stride-S max pool with the same row-tiled
  halo-load schedule as conv (shifted max instead of shifted matmul).
* `global_avgpool` — global average pool with an attenuation coefficient.
  ACL had no global pooling; the paper implemented it from scratch and
  folded the removed dropout layer into an attenuation coefficient applied
  after pool10.  We reproduce exactly that operator.

Pool padding uses -inf (not 0) for the tile-safety rows so ragged tiles
can never leak padded values into a max.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _maxpool_kernel(x_ref, o_ref, *, th, stride, k, w_out):
    """One grid step: (TH, W_out, C) max-pool tile via shifted max."""
    h = pl.program_id(1)
    row0 = h * th * stride
    rows_in = (th - 1) * stride + k
    x_tile = pl.load(
        x_ref, (0, pl.dslice(row0, rows_in), slice(None), slice(None))
    )  # (rows_in, W_in, C)

    c = x_tile.shape[-1]
    out = jnp.full((th, w_out, c), -jnp.inf, dtype=jnp.float32)
    for di in range(k):
        for dj in range(k):
            patch = jax.lax.slice(
                x_tile,
                (di, dj, 0),
                (di + (th - 1) * stride + 1,
                 dj + (w_out - 1) * stride + 1,
                 c),
                (stride, stride, 1),
            )
            out = jnp.maximum(out, patch.astype(jnp.float32))
    o_ref[0] = out.astype(o_ref.dtype)


def maxpool2d(
    x: jax.Array,
    *,
    window: int = 3,
    stride: int = 2,
    row_tile: int | None = None,
) -> jax.Array:
    """VALID max pool, NHWC.  SqueezeNet uses 3x3/s2 everywhere."""
    common.assert_nhwc(x)
    n, h_in, w_in, c = x.shape
    k = window
    h_out = common.conv_out_dim(h_in, k, stride, 0)
    w_out = common.conv_out_dim(w_in, k, stride, 0)
    if h_out <= 0 or w_out <= 0:
        raise ValueError(f"pool output empty: in={x.shape} k={k} s={stride}")

    th = min(row_tile or common.pick_row_tile(h_out, w_out, c), h_out)
    n_tiles = common.ceil_div(h_out, th)
    extra = common.pad_rows_for_tiles(h_in, n_tiles, th, stride, k)
    # -inf padding: ragged-tile max can never see it as a winner.
    xp = jnp.pad(x, ((0, 0), (0, extra), (0, 0), (0, 0)),
                 constant_values=-jnp.inf)
    h_pad = xp.shape[1]

    return pl.pallas_call(
        functools.partial(_maxpool_kernel, th=th, stride=stride, k=k,
                          w_out=w_out),
        grid=(n, n_tiles),
        in_specs=[
            pl.BlockSpec((1, h_pad, w_in, c), lambda i, j: (i, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, th, w_out, c), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h_out, w_out, c), x.dtype),
        interpret=True,
    )(xp)


def _gap_kernel(x_ref, o_ref, *, attenuation, hw):
    """One grid step: one batch element's global average pool."""
    x = x_ref[0]  # (H, W, C)
    s = jnp.sum(x.astype(jnp.float32), axis=(0, 1))
    o_ref[0] = (s * (attenuation / hw)).astype(o_ref.dtype)


def global_avgpool(
    x: jax.Array,
    *,
    attenuation: float = 1.0,
) -> jax.Array:
    """Global average pool + attenuation coefficient, NHWC -> NC.

    `attenuation` reproduces the paper's dropout compensation (the dropout
    layer is deleted for inference; its expected scaling is folded in here).
    """
    common.assert_nhwc(x)
    n, h, w, c = x.shape
    return pl.pallas_call(
        functools.partial(_gap_kernel, attenuation=attenuation, hw=float(h * w)),
        grid=(n,),
        in_specs=[pl.BlockSpec((1, h, w, c), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c), x.dtype),
        interpret=True,
    )(x)
