"""Pure-jnp reference oracle for every L1 kernel.

This module is the correctness contract of the kernel library ("ACL" layer):
each Pallas kernel in this package has an exact pure-`jax.numpy` twin here,
written with maximal clarity and zero performance tricks.  `python/tests/`
sweeps shapes and dtypes with hypothesis and asserts `allclose` between the
Pallas kernel (interpret=True) and these functions.

Layout convention: NHWC everywhere (the paper's ACL engine is also
channels-last on NEON).  Weights for a KxK conv are `(K, K, Cin, Cout)`;
biases are `(Cout,)`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Elementwise / activation
# ---------------------------------------------------------------------------

def relu(x: jax.Array) -> jax.Array:
    """Rectified linear unit."""
    return jnp.maximum(x, 0.0)


def softmax(x: jax.Array, axis: int = -1) -> jax.Array:
    """Numerically stable softmax along `axis`."""
    m = jnp.max(x, axis=axis, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=axis, keepdims=True)


# ---------------------------------------------------------------------------
# Convolution
# ---------------------------------------------------------------------------

def conv2d(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array | None = None,
    *,
    stride: int = 1,
    padding: str | int = "VALID",
    activation: str | None = None,
) -> jax.Array:
    """2-D convolution, NHWC x (K,K,Cin,Cout) -> NHWC.

    `padding` is "VALID", "SAME", or an explicit symmetric pad count.
    `activation` is None or "relu" (the only activation SqueezeNet uses).
    """
    if isinstance(padding, int):
        pad = ((padding, padding), (padding, padding))
    elif padding == "SAME":
        k = w.shape[0]
        p = (k - 1) // 2
        pr = k - 1 - p
        pad = ((p, pr), (p, pr))
    elif padding == "VALID":
        pad = ((0, 0), (0, 0))
    else:  # pragma: no cover - guarded by callers
        raise ValueError(f"bad padding {padding!r}")

    out = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=pad,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if b is not None:
        out = out + b
    if activation == "relu":
        out = relu(out)
    elif activation is not None:  # pragma: no cover
        raise ValueError(f"bad activation {activation!r}")
    return out


def pointwise_conv(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array | None = None,
    *,
    activation: str | None = None,
) -> jax.Array:
    """1x1 convolution as an explicit matmul over the channel axis.

    `w` is `(1, 1, Cin, Cout)` or `(Cin, Cout)`.
    """
    if w.ndim == 4:
        w = w[0, 0]
    out = jnp.einsum("nhwc,cd->nhwd", x, w)
    if b is not None:
        out = out + b
    if activation == "relu":
        out = relu(out)
    return out


# ---------------------------------------------------------------------------
# Pooling
# ---------------------------------------------------------------------------

def maxpool2d(x: jax.Array, *, window: int = 3, stride: int = 2) -> jax.Array:
    """VALID max-pooling, NHWC."""
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(1, window, window, 1),
        window_strides=(1, stride, stride, 1),
        padding="VALID",
    )


def global_avgpool(x: jax.Array, *, attenuation: float = 1.0) -> jax.Array:
    """Global average pool over H and W, times an attenuation coefficient.

    The attenuation coefficient reproduces the paper's dropout substitution:
    dropout is removed at inference and compensated by scaling the pooled
    activations (Section "Building Inference Engine with the ARM Compute
    Library", Figure 2 discussion).
    """
    return jnp.mean(x, axis=(1, 2)) * attenuation


# ---------------------------------------------------------------------------
# Fire module (SqueezeNet)
# ---------------------------------------------------------------------------

def fire(
    x: jax.Array,
    ws: jax.Array,
    bs: jax.Array,
    w1: jax.Array,
    b1: jax.Array,
    w3: jax.Array,
    b3: jax.Array,
) -> jax.Array:
    """SqueezeNet fire module: squeeze 1x1 -> ReLU -> {expand 1x1, expand 3x3
    (SAME)} -> ReLU -> channel concat.

    This reference version *does* use an explicit `concatenate`; the Pallas
    kernel's whole point (and the paper's) is to avoid that copy by writing
    the two expand branches into disjoint channel slices of one buffer.
    """
    s = conv2d(x, ws, bs, activation="relu")
    e1 = conv2d(s, w1, b1, activation="relu")
    e3 = conv2d(s, w3, b3, padding="SAME", activation="relu")
    return jnp.concatenate([e1, e3], axis=-1)


# ---------------------------------------------------------------------------
# Quantization (Fig 4 substrate)
# ---------------------------------------------------------------------------

def quant_scale(x: jax.Array | np.ndarray) -> float:
    """Symmetric per-tensor int8 scale: max(|x|) / 127."""
    m = float(jnp.max(jnp.abs(x)))
    return m / 127.0 if m > 0 else 1.0


def quantize(x: jax.Array, scale: float) -> jax.Array:
    """f32 -> int8 with symmetric scale (round-to-nearest-even, clipped)."""
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    return q.astype(jnp.int8)


def dequantize(q: jax.Array, scale: float) -> jax.Array:
    """int8/int32 -> f32."""
    return q.astype(jnp.float32) * scale


def conv2d_q8(
    xq: jax.Array,
    wq: jax.Array,
    b: jax.Array | None,
    x_scale: float,
    w_scale: float,
    *,
    stride: int = 1,
    padding: str | int = "VALID",
    activation: str | None = None,
) -> jax.Array:
    """Quantized conv: int8 x int8 -> int32 accumulate -> rescale to f32.

    Mirrors the paper's "vector quantization" TensorFlow experiment: the
    conv itself runs on 8-bit data, but a de-quantize (rescale) step is
    required on the way out — the overhead Fig 4 measures.
    """
    if isinstance(padding, int):
        pad = ((padding, padding), (padding, padding))
    elif padding == "SAME":
        k = wq.shape[0]
        p = (k - 1) // 2
        pad = ((p, k - 1 - p), (p, k - 1 - p))
    else:
        pad = ((0, 0), (0, 0))
    acc = jax.lax.conv_general_dilated(
        xq.astype(jnp.int32),
        wq.astype(jnp.int32),
        window_strides=(stride, stride),
        padding=pad,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.int32,
    )
    out = acc.astype(jnp.float32) * (x_scale * w_scale)
    if b is not None:
        out = out + b
    if activation == "relu":
        out = relu(out)
    return out


def fire_q8(x, ws, bs, w1, b1, w3, b3, scales):
    """Quantized fire module: quantize -> q8 convs -> dequantized f32 out.

    `scales` maps tensor-name -> symmetric int8 scale; see
    python/compile/quantize.py for calibration.  Activations are re-quantized
    between the squeeze and expand stages — exactly the re-quantize overhead
    the paper attributes the Fig 4 slowdown to.
    """
    xs = scales["x"]
    xq = quantize(x, xs)
    s = conv2d_q8(xq, quantize(ws, scales["ws"]), bs, xs, scales["ws"],
                  activation="relu")
    ss = scales["s"]
    sq = quantize(s, ss)
    e1 = conv2d_q8(sq, quantize(w1, scales["w1"]), b1, ss, scales["w1"],
                   activation="relu")
    e3 = conv2d_q8(sq, quantize(w3, scales["w3"]), b3, ss, scales["w3"],
                   padding="SAME", activation="relu")
    return jnp.concatenate([e1, e3], axis=-1)
