"""Pallas int8 quantization kernels (the Fig 4 substrate).

The paper's Fig 4 experiment applies "vector quantization" [Han et al.] to
TensorFlow's convolutions: 8-bit weights let NEON process 4x more lanes per
instruction, making conv ~25% faster, but every quantized op needs
re-quantize / de-quantize steps whose cost exceeds the win — end-to-end
inference gets >100 ms slower.

We reproduce the *structure* exactly:

* `quantize`   — f32 -> int8 (symmetric per-tensor scale), an explicit op.
* `dequantize` — int8/int32 -> f32, an explicit op.
* `conv2d_q8`  — shifted-matmul conv on int8 operands accumulating in
  int32, then rescaling.  Same schedule as conv.py but the MXU-shaped
  inner matmul runs on 8-bit data (on a real TPU this is the int8 MXU
  path with 4x the f32 throughput — DESIGN.md §Hardware-Adaptation).

Hardware note: under CPU-PJRT the int8 dot gains little, so the Fig 4
bench reports both the measured ratio and the paper-scaled ratio (NEON
8-bit SIMD width modelled as 1.25x conv speedup, the paper's own number).
The *overhead* side (quantize/requantize/dequantize ops) is fully measured.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _quantize_kernel(x_ref, o_ref, *, inv_scale):
    q = jnp.clip(jnp.round(x_ref[...] * inv_scale), -127.0, 127.0)
    o_ref[...] = q.astype(jnp.int8)


def quantize(x: jax.Array, scale: float, *, row_tile: int | None = None) -> jax.Array:
    """f32 -> int8 with symmetric per-tensor scale (explicit op)."""
    shape = x.shape
    flat = x.reshape(-1)
    m = flat.shape[0]
    tm = min(row_tile or (1 << 16), m)
    out = pl.pallas_call(
        functools.partial(_quantize_kernel, inv_scale=1.0 / scale),
        grid=(common.ceil_div(m, tm),),
        in_specs=[pl.BlockSpec((tm,), lambda i: (i,))],
        out_specs=pl.BlockSpec((tm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.int8),
        interpret=True,
    )(flat)
    return out.reshape(shape)


def _dequantize_kernel(q_ref, o_ref, *, scale):
    o_ref[...] = q_ref[...].astype(jnp.float32) * scale


def dequantize(q: jax.Array, scale: float, *, row_tile: int | None = None) -> jax.Array:
    """int8/int32 -> f32 (explicit op)."""
    shape = q.shape
    flat = q.reshape(-1)
    m = flat.shape[0]
    tm = min(row_tile or (1 << 16), m)
    out = pl.pallas_call(
        functools.partial(_dequantize_kernel, scale=scale),
        grid=(common.ceil_div(m, tm),),
        in_specs=[pl.BlockSpec((tm,), lambda i: (i,))],
        out_specs=pl.BlockSpec((tm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        interpret=True,
    )(flat)
    return out.reshape(shape)


def _conv2d_q8_kernel(x_ref, w_ref, b_ref, o_ref, *, th, stride, k, w_out,
                      rescale, activation):
    """Int8 shifted-matmul conv tile with int32 accumulation."""
    hgrid = pl.program_id(1)
    row0 = hgrid * th * stride
    rows_in = (th - 1) * stride + k
    x_tile = pl.load(
        x_ref, (0, pl.dslice(row0, rows_in), slice(None), slice(None))
    )  # (rows_in, W_pad, Cin) int8

    cin = x_tile.shape[-1]
    cout = o_ref.shape[-1]
    acc = jnp.zeros((th * w_out, cout), dtype=jnp.int32)
    for di in range(k):
        for dj in range(k):
            patch = jax.lax.slice(
                x_tile,
                (di, dj, 0),
                (di + (th - 1) * stride + 1,
                 dj + (w_out - 1) * stride + 1,
                 cin),
                (stride, stride, 1),
            )
            acc = acc + jax.lax.dot_general(
                patch.reshape(th * w_out, cin),
                w_ref[di, dj],
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32,
            )
    out = acc.astype(jnp.float32) * rescale + b_ref[...]
    if activation == "relu":
        out = jnp.maximum(out, 0.0)
    o_ref[0] = out.reshape(th, w_out, cout).astype(o_ref.dtype)


def conv2d_q8(
    xq: jax.Array,
    wq: jax.Array,
    b: jax.Array | None,
    x_scale: float,
    w_scale: float,
    *,
    stride: int = 1,
    padding: str | int = "VALID",
    activation: str | None = None,
    row_tile: int | None = None,
) -> jax.Array:
    """Quantized KxK conv: int8 NHWC x int8 (K,K,Cin,Cout) -> f32 NHWC."""
    common.assert_nhwc(xq)
    assert xq.dtype == jnp.int8 and wq.dtype == jnp.int8, (xq.dtype, wq.dtype)
    n, h_in, w_in, cin = xq.shape
    k, _, _, cout = wq.shape
    if b is None:
        b = jnp.zeros((cout,), dtype=jnp.float32)

    plo, phi = common.resolve_padding(padding, k)
    h_out = (h_in + plo + phi - k) // stride + 1
    w_out = (w_in + plo + phi - k) // stride + 1
    th = min(row_tile or common.pick_row_tile(h_out, w_out, cout), h_out)
    n_tiles = common.ceil_div(h_out, th)
    extra = common.pad_rows_for_tiles(h_in + plo + phi, n_tiles, th, stride, k)
    xp = jnp.pad(xq, ((0, 0), (plo, phi + extra), (plo, phi), (0, 0)))
    h_pad, w_pad = xp.shape[1], xp.shape[2]

    return pl.pallas_call(
        functools.partial(
            _conv2d_q8_kernel, th=th, stride=stride, k=k, w_out=w_out,
            rescale=x_scale * w_scale, activation=activation,
        ),
        grid=(n, n_tiles),
        in_specs=[
            pl.BlockSpec((1, h_pad, w_pad, cin), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((k, k, cin, cout), lambda i, j: (0, 0, 0, 0)),
            pl.BlockSpec((cout,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((1, th, w_out, cout), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h_out, w_out, cout), jnp.float32),
        interpret=True,
    )(xp, wq, b)


def _dequant_bias_kernel(x_ref, b_ref, o_ref, *, scale, activation):
    out = x_ref[...] * scale + b_ref[...]
    if activation == "relu":
        out = jnp.maximum(out, 0.0)
    o_ref[...] = out.astype(o_ref.dtype)


def dequant_bias(
    acc: jax.Array,
    b: jax.Array,
    scale: float,
    *,
    activation: str | None = None,
) -> jax.Array:
    """De-quantize a raw conv accumulator and add the f32 bias.

    This is the explicit "de-quantize" node of the paper's Fig 4 graph:
    `out = acc * (x_scale*w_scale) + bias`, channelwise bias over NHWC.
    Kept as its own op (not fused into conv_q8) so the overhead the paper
    blames for the slowdown is separately schedulable and measurable.
    """
    common.assert_nhwc(acc)
    n, h, w, c = acc.shape
    return pl.pallas_call(
        functools.partial(_dequant_bias_kernel, scale=scale,
                          activation=activation),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, h, w, c), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((c,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, h, w, c), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h, w, c), jnp.float32),
        interpret=True,
    )(acc, b)
