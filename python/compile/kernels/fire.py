"""Fused fire-module Pallas kernel — the heart of the paper's engine.

SqueezeNet's fire module (paper Figure 1) is:

    squeeze 1x1 conv + ReLU
      -> expand 1x1 conv + ReLU   \
      -> expand 3x3 conv + ReLU   /  channel concat

A framework executes this as five ops plus a `concatenate` that copies
both expand outputs into a fresh buffer.  The paper's ACL engine "eliminates
the need for extra memory copy otherwise needed for concatenation" — it
writes each expand branch directly into its channel slice of the shared
output buffer.  This kernel reproduces that: one `pallas_call` computes the
whole module and writes `o_ref[..., :E1]` / `o_ref[..., E1:]` without any
concat op existing in the lowered HLO.

Tiling: grid = (N, ceil(H/TH)).  The expand-3x3 branch needs a one-row halo
of *squeeze* output, so each grid step computes squeeze on TH+2 input rows
(edge rows masked to zero — squeezing a zero-padded input row would give
relu(bias) != 0 and corrupt the edge, so masking is done *after* the
squeeze, not by padding the input).  W is zero-padded inside the kernel for
the SAME 3x3.

VMEM per step (floats): (TH+2)*W*Cin   input rows
                      + Cin*S + 3*3*S*E3 + S*E1   weights
                      + (TH+2)*(W+2)*S            squeeze scratch
                      + TH*W*(E1+E3)              output tile
For the largest fire (fire8: W=27, Cin=512, S=64, E=256) at TH=8 this is
~1.1 MiB — comfortably inside the 16 MiB budget (DESIGN.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _fire_kernel(x_ref, ws_ref, bs_ref, w1_ref, b1_ref, w3_ref, b3_ref,
                 o_ref, *, th, h, e1):
    """One grid step: TH output rows of a full fire module."""
    t = pl.program_id(1)
    row0 = t * th  # first output row of this tile

    # ---- squeeze on TH+2 rows centred on the tile (halo for the 3x3) ----
    # Loaded window starts one row above the tile; the input was pre-padded
    # by one zero row on top, so ref row (row0) == image row (row0 - 1).
    x_tile = pl.load(
        x_ref, (0, pl.dslice(row0, th + 2), slice(None), slice(None))
    )  # (TH+2, W, Cin)
    w = x_tile.shape[1]
    cin = x_tile.shape[2]
    s_ch = ws_ref.shape[-1]

    sq = jnp.dot(
        x_tile.reshape((th + 2) * w, cin),
        ws_ref[...],
        preferred_element_type=jnp.float32,
    ).reshape(th + 2, w, s_ch) + bs_ref[...]
    sq = jnp.maximum(sq, 0.0)

    # Mask halo rows that fall outside the real image: global squeeze row
    # index of local row r is (row0 - 1 + r); valid iff 0 <= it < H.
    gr = row0 - 1 + jnp.arange(th + 2).reshape(th + 2, 1, 1)
    sq = jnp.where((gr >= 0) & (gr < h), sq, 0.0)

    # ---- expand 1x1 on the middle TH rows -> channels [0, E1) ----
    mid = jax.lax.slice(sq, (1, 0, 0), (1 + th, w, s_ch))
    exp1 = jnp.dot(
        mid.reshape(th * w, s_ch), w1_ref[...],
        preferred_element_type=jnp.float32,
    ).reshape(th, w, e1) + b1_ref[...]
    exp1 = jnp.maximum(exp1, 0.0)

    # ---- expand 3x3 (SAME) on the halo'd squeeze -> channels [E1, end) ----
    sqp = jnp.pad(sq, ((0, 0), (1, 1), (0, 0)))  # zero-pad W for SAME
    e3 = w3_ref.shape[-1]
    acc = jnp.zeros((th * w, e3), dtype=jnp.float32)
    for di in range(3):
        for dj in range(3):
            patch = jax.lax.slice(
                sqp, (di, dj, 0), (di + th, dj + w, s_ch)
            )  # (TH, W, S)
            acc = acc + jnp.dot(
                patch.reshape(th * w, s_ch), w3_ref[di, dj],
                preferred_element_type=jnp.float32,
            )
    exp3 = jnp.maximum(acc.reshape(th, w, e3) + b3_ref[...], 0.0)

    # ---- concat-free writes into channel slices of the shared buffer ----
    o_ref[0, :, :, :e1] = exp1.astype(o_ref.dtype)
    o_ref[0, :, :, e1:] = exp3.astype(o_ref.dtype)


def fire(
    x: jax.Array,
    ws: jax.Array, bs: jax.Array,
    w1: jax.Array, b1: jax.Array,
    w3: jax.Array, b3: jax.Array,
    *,
    row_tile: int | None = None,
) -> jax.Array:
    """Fused SqueezeNet fire module (squeeze+expand+implicit concat).

    Shapes: x (N,H,W,Cin); ws (1,1,Cin,S) or (Cin,S); w1 (1,1,S,E1) or
    (S,E1); w3 (3,3,S,E3).  Output (N,H,W,E1+E3).
    """
    common.assert_nhwc(x)
    if ws.ndim == 4:
        ws = ws[0, 0]
    if w1.ndim == 4:
        w1 = w1[0, 0]
    n, h, w, cin = x.shape
    s_ch = ws.shape[-1]
    e1 = w1.shape[-1]
    e3 = w3.shape[-1]
    assert ws.shape == (cin, s_ch), (ws.shape, cin)
    assert w3.shape == (3, 3, s_ch, e3), w3.shape

    th = min(row_tile or common.pick_row_tile(h, w, e1 + e3), h)
    n_tiles = common.ceil_div(h, th)
    # One zero row on top (halo offset) + tile-safety rows at the bottom:
    # the last tile loads rows [row0, row0 + TH + 2).
    need = (n_tiles - 1) * th + th + 2
    xp = jnp.pad(x, ((0, 0), (1, max(0, need - (h + 1))), (0, 0), (0, 0)))
    h_pad = xp.shape[1]

    return pl.pallas_call(
        functools.partial(_fire_kernel, th=th, h=h, e1=e1),
        grid=(n, n_tiles),
        in_specs=[
            pl.BlockSpec((1, h_pad, w, cin), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((cin, s_ch), lambda i, j: (0, 0)),
            pl.BlockSpec((s_ch,), lambda i, j: (0,)),
            pl.BlockSpec((s_ch, e1), lambda i, j: (0, 0)),
            pl.BlockSpec((e1,), lambda i, j: (0,)),
            pl.BlockSpec((3, 3, s_ch, e3), lambda i, j: (0, 0, 0, 0)),
            pl.BlockSpec((e3,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((1, th, w, e1 + e3), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h, w, e1 + e3), x.dtype),
        interpret=True,
    )(xp, ws, bs, w1, b1, w3, b3)
