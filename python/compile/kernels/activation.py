"""Pallas activation kernels ("ACL Activation" + "ACL Soft-Max").

* `relu` — standalone elementwise ReLU.  The fused conv path folds ReLU
  into the conv kernel; this op exists for the op-by-op baseline graph,
  where TensorFlow-style engines dispatch it separately (that separate
  dispatch is part of what Fig 3 group 1 measures).
* `softmax` — row-tiled numerically-stable softmax, the network's output
  operator (Fig 3 group 2).
* `concat_channels` — explicit channel concatenation as a copy kernel.
  ACL does not need it (the fused fire kernel writes into channel slices);
  the baseline graph *does*, and E6 (concat_ablation) measures exactly
  this copy.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _relu_kernel(x_ref, o_ref):
    o_ref[...] = jnp.maximum(x_ref[...], 0.0).astype(o_ref.dtype)


def relu(x: jax.Array, *, row_tile: int | None = None) -> jax.Array:
    """Elementwise ReLU over an array of any rank (flattened row-tiled)."""
    shape = x.shape
    flat = x.reshape(-1)
    m = flat.shape[0]
    tm = min(row_tile or common.round_up(1 << 16, common.MXU_TILE), m)
    n_tiles = common.ceil_div(m, tm)
    out = pl.pallas_call(
        _relu_kernel,
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((tm,), lambda i: (i,))],
        out_specs=pl.BlockSpec((tm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), x.dtype),
        interpret=True,
    )(flat)
    return out.reshape(shape)


def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    o_ref[...] = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(o_ref.dtype)


def softmax(x: jax.Array) -> jax.Array:
    """Stable softmax along the last axis of a 2-D (N, C) array."""
    assert x.ndim == 2, f"softmax expects (N, C), got {x.shape}"
    n, c = x.shape
    return pl.pallas_call(
        _softmax_kernel,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, c), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c), x.dtype),
        interpret=True,
    )(x)


def _concat_kernel(a_ref, b_ref, o_ref, *, ca):
    """Explicit copy of both inputs into the output's channel slices."""
    o_ref[0, :, :, :ca] = a_ref[0]
    o_ref[0, :, :, ca:] = b_ref[0]


def concat_channels(a: jax.Array, b: jax.Array) -> jax.Array:
    """Channel concat as an explicit materializing copy (baseline op).

    The paper's from-scratch engine eliminates this operator entirely; it
    exists here so the TF-baseline graph pays the same copy TensorFlow's
    generic concat pays.
    """
    common.assert_nhwc(a)
    common.assert_nhwc(b)
    n, h, w, ca = a.shape
    nb, hb, wb, cb = b.shape
    assert (n, h, w) == (nb, hb, wb), (a.shape, b.shape)
    return pl.pallas_call(
        functools.partial(_concat_kernel, ca=ca),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, h, w, ca), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, h, w, cb), lambda i: (i, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, w, ca + cb), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h, w, ca + cb), a.dtype),
        interpret=True,
    )(a, b)


def _scale_kernel(x_ref, o_ref, *, c):
    o_ref[...] = x_ref[...] * c


def scale_mul(x: jax.Array, c: float, *, row_tile: int | None = None) -> jax.Array:
    """Elementwise multiply by a compile-time constant.

    The baseline graph's standalone "attenuation" op: a framework keeps the
    dropout-compensation scale as its own node; the ACL engine folds it into
    the global-pool kernel (pool.py).  E5/dispatch_overhead measures the
    difference.
    """
    shape = x.shape
    flat = x.reshape(-1)
    m = flat.shape[0]
    tm = min(row_tile or (1 << 16), m)
    out = pl.pallas_call(
        functools.partial(_scale_kernel, c=c),
        grid=(common.ceil_div(m, tm),),
        in_specs=[pl.BlockSpec((tm,), lambda i: (i,))],
        out_specs=pl.BlockSpec((tm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), x.dtype),
        interpret=True,
    )(flat)
    return out.reshape(shape)
