"""L2 — primitive op-graph for the TF-baseline engine (and its quant variant).

The paper's comparator is a *ported framework*: TensorFlow executes
SqueezeNet as a graph of primitive ops — every conv, ReLU, pool, and an
explicit `concatenate` per fire module — each dispatched separately by a
generic graph interpreter.  This module declares that graph.  `aot.py`
lowers **one HLO executable per op**, and the Rust `TfBaselineEngine` walks
the graph exactly the way a framework runtime does (dynamic tensor
registry, per-op dispatch, intermediate materialization).

Fairness note (DESIGN.md): every op lowers from the *same* L1 Pallas
kernels the ACL engine uses, so any measured difference between engines is
pure structure — dispatch count, concat copies, lost fusion — never kernel
quality.  That mirrors the paper's "both engines use NEON" control.

The quant variant reproduces Fig 4's graph surgery: every conv op becomes
    quantize (f32->int8)  ->  conv_q8 (int8 x int8 -> raw acc)
        ->  dequantize+bias (acc * s_x*s_w + b)
with ReLU kept separate, exactly the Quantize/Dequantize node insertion
TensorFlow's 8-bit path performs.

Op groups follow Fig 3's breakdown:
    group1 = convolution, ReLU, concatenate
    group2 = pooling (max/global/attenuation) and soft-max
    quant  = the inserted quantize/dequantize overhead ops (Fig 4)
"""

from __future__ import annotations

import dataclasses
from typing import Any

from . import model

GROUP1 = "group1"
GROUP2 = "group2"
QUANT = "quant"

# op kind -> group (Fig 3 classification)
KIND_GROUPS = {
    "conv": GROUP1, "conv_q8": GROUP1, "relu": GROUP1, "concat": GROUP1,
    "maxpool": GROUP2, "gap": GROUP2, "atten": GROUP2, "softmax": GROUP2,
    "quantize": QUANT, "dequant_bias": QUANT,
}


@dataclasses.dataclass(frozen=True)
class OpSpec:
    """One primitive op of the baseline graph.

    inputs are producer op names, or the literal "input" for the image.
    Shapes are batch-less (HWC, or (C,) after the pool); dtypes are the
    edge dtypes ("f32" or "i8") the Rust registry must allocate.
    """
    index: int
    name: str
    kind: str
    inputs: tuple[str, ...]
    param_names: tuple[str, ...]
    attrs: dict[str, Any]
    in_shapes: tuple[tuple[int, ...], ...]
    out_shape: tuple[int, ...]
    in_dtypes: tuple[str, ...]
    out_dtype: str

    @property
    def group(self) -> str:
        return KIND_GROUPS[self.kind]


class _Builder:
    def __init__(self) -> None:
        self.ops: list[OpSpec] = []
        self.shapes: dict[str, tuple[int, ...]] = {}
        self.dtypes: dict[str, str] = {}

    def emit(self, name, kind, inputs, params=(), attrs=None, out_shape=None,
             out_dtype="f32"):
        attrs = attrs or {}
        in_shapes = tuple(self.shapes[i] for i in inputs)
        in_dtypes = tuple(self.dtypes[i] for i in inputs)
        op = OpSpec(
            index=len(self.ops), name=name, kind=kind, inputs=tuple(inputs),
            param_names=tuple(params), attrs=attrs, in_shapes=in_shapes,
            out_shape=tuple(out_shape), in_dtypes=in_dtypes,
            out_dtype=out_dtype,
        )
        self.ops.append(op)
        self.shapes[name] = tuple(out_shape)
        self.dtypes[name] = out_dtype
        return name


def _conv_out_hw(h: int, k: int, stride: int, same: bool) -> int:
    if same:
        return -(-h // stride)
    return (h - k) // stride + 1


def _emit_conv(b: _Builder, name: str, src: str, wname: str, bname: str,
               k: int, stride: int, same: bool, cout: int,
               quant: bool) -> str:
    """Emit a conv (+ separate relu) in fp32 or quantized form.

    Returns the name of the post-ReLU op.
    """
    h, _, _ = b.shapes[src]
    ho = _conv_out_hw(h, k, stride, same)
    conv_attrs = {"k": k, "stride": stride,
                  "padding": "SAME" if same else "VALID"}
    if not quant:
        b.emit(f"{name}", "conv", [src], [wname, bname], conv_attrs,
               (ho, ho, cout))
    else:
        # Fig 4 graph surgery: quantize -> conv_q8(raw) -> dequant+bias.
        # Scales are calibration outputs; aot.py injects the numeric values
        # into attrs at lowering time (manifest carries them for Rust).
        q = b.emit(f"{name}_quantize", "quantize", [src], [],
                   {"scale_key": f"{name}:in"}, b.shapes[src], out_dtype="i8")
        raw = b.emit(f"{name}_q8", "conv_q8", [q], [wname + "_q8"],
                     {**conv_attrs, "w_scale_key": f"{name}:w"},
                     (ho, ho, cout))
        b.emit(f"{name}", "dequant_bias", [raw], [bname],
               {"scale_key": f"{name}:deq"}, (ho, ho, cout))
    return b.emit(f"{name}_relu", "relu", [name], [], {},
                  b.shapes[name])


def build_graph(quant: bool = False) -> list[OpSpec]:
    """The SqueezeNet op graph a framework executes (fp32 or quantized)."""
    b = _Builder()
    b.shapes["input"] = (model.INPUT_HW, model.INPUT_HW, 3)
    b.dtypes["input"] = "f32"

    y = _emit_conv(b, "conv1", "input", "conv1_w", "conv1_b",
                   k=7, stride=2, same=False, cout=96, quant=quant)
    h = b.shapes[y][0]
    hp = (h - 3) // 2 + 1
    y = b.emit("pool1", "maxpool", [y], [], {"window": 3, "stride": 2},
               (hp, hp, 96))

    for f in model.FIRES:
        s = _emit_conv(b, f"{f.name}_squeeze", y, f"{f.name}_sw",
                       f"{f.name}_sb", k=1, stride=1, same=False,
                       cout=f.squeeze, quant=quant)
        e1 = _emit_conv(b, f"{f.name}_expand1", s, f"{f.name}_e1w",
                        f"{f.name}_e1b", k=1, stride=1, same=False,
                        cout=f.expand1, quant=quant)
        e3 = _emit_conv(b, f"{f.name}_expand3", s, f"{f.name}_e3w",
                        f"{f.name}_e3b", k=3, stride=1, same=True,
                        cout=f.expand3, quant=quant)
        h = b.shapes[e1][0]
        y = b.emit(f"{f.name}_concat", "concat", [e1, e3], [], {},
                   (h, h, f.cout))
        if f.name in model.POOL_AFTER:
            hp = (h - 3) // 2 + 1
            y = b.emit(f"{f.name}_pool", "maxpool", [y], [],
                       {"window": 3, "stride": 2}, (hp, hp, f.cout))

    y = _emit_conv(b, "conv10", y, "conv10_w", "conv10_b", k=1, stride=1,
                   same=False, cout=model.NUM_CLASSES, quant=quant)
    y = b.emit("gap", "gap", [y], [], {"attenuation": 1.0},
               (model.NUM_CLASSES,))
    y = b.emit("atten", "atten", [y], [],
               {"scale": model.ATTENUATION}, (model.NUM_CLASSES,))
    b.emit("softmax", "softmax", [y], [], {}, (model.NUM_CLASSES,))
    return b.ops


def lower_fn(op: OpSpec, scales: dict[str, float] | None = None):
    """Build the jax function for one op (lowered by aot.py).

    Signature: fn(*params, x...) with params first (matches stage lowering).
    `scales` supplies calibration values for quantized ops.
    """
    from . import kernels  # local import: keeps graph.py importable cheaply

    k = op.kind
    a = op.attrs
    if k == "conv":
        def fn(w, bias, x):
            return kernels.conv2d(x, w, bias, stride=a["stride"],
                                  padding=a["padding"])
    elif k == "conv_q8":
        w_scale = scales[a["w_scale_key"]]
        del w_scale  # raw accumulate; scale applied by dequant_bias
        def fn(wq, x):
            return kernels.conv2d_q8(x, wq, None, 1.0, 1.0,
                                     stride=a["stride"], padding=a["padding"])
    elif k == "relu":
        def fn(x):
            return kernels.relu(x)
    elif k == "maxpool":
        def fn(x):
            return kernels.maxpool2d(x, window=a["window"], stride=a["stride"])
    elif k == "concat":
        def fn(x, y):
            return kernels.concat_channels(x, y)
    elif k == "gap":
        def fn(x):
            return kernels.global_avgpool(x, attenuation=a["attenuation"])
    elif k == "atten":
        def fn(x):
            return kernels.scale_mul(x, a["scale"])
    elif k == "softmax":
        def fn(x):
            return kernels.softmax(x)
    elif k == "quantize":
        s = scales[a["scale_key"]]
        def fn(x):
            return kernels.quantize(x, s)
    elif k == "dequant_bias":
        s = scales[a["scale_key"]]
        def fn(bias, x):
            return kernels.dequant_bias(x, bias, s)
    else:  # pragma: no cover
        raise ValueError(f"unknown op kind {k}")
    return fn


def graph_stats(ops: list[OpSpec]) -> dict[str, int]:
    """Counts used by tests and DESIGN.md's inventory."""
    out: dict[str, int] = {}
    for op in ops:
        out[op.kind] = out.get(op.kind, 0) + 1
    out["total"] = len(ops)
    return out


def execute_graph(ops: list[OpSpec], params: dict, x,
                  scales: dict[str, float] | None = None) -> dict[str, Any]:
    """Reference interpreter for the op graph (pure-jnp oracle semantics).

    Used to (a) sanity-check the graph wiring in pytest and (b) compute the
    quantized-path goldens the Rust engine validates against.  Returns all
    intermediate tensors keyed by op name.
    """
    import jax.numpy as jnp

    from .kernels import ref

    env: dict[str, Any] = {"input": x}
    for op in ops:
        ins = [env[i] for i in op.inputs]
        a = op.attrs
        if op.kind == "conv":
            w, bias = params[op.param_names[0]], params[op.param_names[1]]
            out = ref.conv2d(ins[0], w, bias, stride=a["stride"],
                             padding=a["padding"])
        elif op.kind == "conv_q8":
            wq = params[op.param_names[0]]
            out = ref.conv2d_q8(ins[0], wq, None, 1.0, 1.0,
                                stride=a["stride"], padding=a["padding"])
        elif op.kind == "relu":
            out = ref.relu(ins[0])
        elif op.kind == "maxpool":
            out = ref.maxpool2d(ins[0], window=a["window"], stride=a["stride"])
        elif op.kind == "concat":
            out = jnp.concatenate(ins, axis=-1)
        elif op.kind == "gap":
            out = ref.global_avgpool(ins[0], attenuation=a["attenuation"])
        elif op.kind == "atten":
            out = ins[0] * a["scale"]
        elif op.kind == "softmax":
            out = ref.softmax(ins[0])
        elif op.kind == "quantize":
            out = ref.quantize(ins[0], scales[a["scale_key"]])
        elif op.kind == "dequant_bias":
            bias = params[op.param_names[0]]
            out = ins[0] * scales[a["scale_key"]] + bias
        else:  # pragma: no cover
            raise ValueError(op.kind)
        env[op.name] = out
    return env
