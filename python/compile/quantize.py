"""Quantization toolkit: weight quantization + activation calibration.

Reproduces the data side of the paper's Fig 4 experiment (8-bit "vector
quantization" after Han et al. [4]): symmetric per-tensor int8 for every
conv weight, and activation scales calibrated by running the fp32 oracle
network over a small synthetic calibration batch and recording per-site
absolute maxima.

Scale keys match `graph.py`'s quantized op attrs:
    "<conv>:in"  — input-activation scale of that conv (for `quantize` ops)
    "<conv>:w"   — weight scale (baked into the int8 weights)
    "<conv>:deq" — in*w product (for `dequant_bias` ops)
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from . import model
from .kernels import ref

# conv-op name -> activation_sites key holding that conv's input.
_CONV_INPUT_SITE: dict[str, str] = {"conv1": "input", "conv10": "conv10_in"}
for _f in model.FIRES:
    _CONV_INPUT_SITE[f"{_f.name}_squeeze"] = f"{_f.name}_in"
    _CONV_INPUT_SITE[f"{_f.name}_expand1"] = f"{_f.name}_squeeze"
    _CONV_INPUT_SITE[f"{_f.name}_expand3"] = f"{_f.name}_squeeze"

# conv-op name -> weight param name.
CONV_WEIGHTS: dict[str, str] = {"conv1": "conv1_w", "conv10": "conv10_w"}
for _f in model.FIRES:
    CONV_WEIGHTS[f"{_f.name}_squeeze"] = f"{_f.name}_sw"
    CONV_WEIGHTS[f"{_f.name}_expand1"] = f"{_f.name}_e1w"
    CONV_WEIGHTS[f"{_f.name}_expand3"] = f"{_f.name}_e3w"


def calibration_batch(n: int = 4, seed: int = 7) -> np.ndarray:
    """Synthetic calibration images, same distribution as the goldens."""
    r = np.random.RandomState(seed)
    return r.uniform(-1.0, 1.0,
                     (n, model.INPUT_HW, model.INPUT_HW, 3)).astype(np.float32)


def quantize_weights(params: dict[str, np.ndarray]):
    """int8-quantize every conv weight.

    Returns (q8 params dict name+'_q8' -> int8 array, weight scales dict
    conv-op-name -> float).
    """
    q8: dict[str, np.ndarray] = {}
    w_scales: dict[str, float] = {}
    for conv, wname in CONV_WEIGHTS.items():
        w = params[wname]
        s = ref.quant_scale(w)
        q8[wname + "_q8"] = np.asarray(ref.quantize(jnp.asarray(w), s))
        w_scales[conv] = s
    return q8, w_scales


def calibrate(params: dict[str, np.ndarray],
              batch: np.ndarray | None = None) -> dict[str, float]:
    """Produce the full scale table for the quantized graph.

    Runs the fp32 oracle over the calibration batch, takes per-site
    max(|act|) across the batch, and combines with weight scales.
    """
    if batch is None:
        batch = calibration_batch()
    sites = model.activation_sites(
        {k: jnp.asarray(v) for k, v in params.items()}, jnp.asarray(batch))
    _, w_scales = quantize_weights(params)

    scales: dict[str, float] = {}
    for conv, site in _CONV_INPUT_SITE.items():
        a = np.asarray(sites[site])
        m = float(np.abs(a).max())
        s_in = m / 127.0 if m > 0 else 1.0
        scales[f"{conv}:in"] = s_in
        scales[f"{conv}:w"] = w_scales[conv]
        scales[f"{conv}:deq"] = s_in * w_scales[conv]
    return scales
