"""L2 — SqueezeNet v1.0 in JAX, composed from the L1 Pallas kernels.

The paper builds SqueezeNet (227x227x3 input, the v1.0 layout its Figure 2
shows) from ACL building blocks.  This module is the analogous composition:

* `ARCH` / `STAGES` — the declarative network description.  The Rust
  coordinator reads the same structure from `manifest.json`; this module is
  the single source of truth.
* `init_params` — deterministic He-initialized synthetic weights (the paper
  never evaluates accuracy, only latency; see DESIGN.md §Substitutions).
* `stage_fns` — one fused jax function per *stage* (conv1-block, each fire
  module with any trailing maxpool folded in, the conv10/pool/softmax
  head).  These lower to the per-stage HLO executables the ACL engine runs.
* `forward_fused` — the whole network as one function (fully-fused
  ablation artifact, and the oracle path for golden outputs).
* `forward_ref` — same network on the pure-jnp oracle ops (fast-compiling
  reference used for calibration and goldens).

Dropout: removed for inference; compensated by `ATTENUATION` applied inside
the global-average-pool stage, exactly the paper's trick (Figure 2).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import kernels
from .kernels import ref

INPUT_HW = 227
INPUT_SHAPE = (INPUT_HW, INPUT_HW, 3)  # HWC, batch added per artifact
NUM_CLASSES = 1000
ATTENUATION = 0.5  # dropout keep-probability folded in after pool10
SEED = 42


@dataclasses.dataclass(frozen=True)
class FireSpec:
    """Squeeze/expand widths of one fire module (paper Figure 1)."""
    name: str
    cin: int
    squeeze: int
    expand1: int
    expand3: int

    @property
    def cout(self) -> int:
        return self.expand1 + self.expand3


# SqueezeNet v1.0 fire ladder (Iandola et al., Table 1).
FIRES: tuple[FireSpec, ...] = (
    FireSpec("fire2", 96, 16, 64, 64),
    FireSpec("fire3", 128, 16, 64, 64),
    FireSpec("fire4", 128, 32, 128, 128),
    FireSpec("fire5", 256, 32, 128, 128),
    FireSpec("fire6", 256, 48, 192, 192),
    FireSpec("fire7", 384, 48, 192, 192),
    FireSpec("fire8", 384, 64, 256, 256),
    FireSpec("fire9", 512, 64, 256, 256),
)

# Maxpool sites: pool1 after conv1, pool4 after fire4, pool8 after fire8.
POOL_AFTER = {"conv1", "fire4", "fire8"}


def param_specs() -> list[tuple[str, tuple[int, ...]]]:
    """Deterministic (name, shape) list — the manifest/weights.bin order."""
    specs: list[tuple[str, tuple[int, ...]]] = [
        ("conv1_w", (7, 7, 3, 96)),
        ("conv1_b", (96,)),
    ]
    for f in FIRES:
        specs += [
            (f"{f.name}_sw", (1, 1, f.cin, f.squeeze)),
            (f"{f.name}_sb", (f.squeeze,)),
            (f"{f.name}_e1w", (1, 1, f.squeeze, f.expand1)),
            (f"{f.name}_e1b", (f.expand1,)),
            (f"{f.name}_e3w", (3, 3, f.squeeze, f.expand3)),
            (f"{f.name}_e3b", (f.expand3,)),
        ]
    specs += [
        ("conv10_w", (1, 1, 512, NUM_CLASSES)),
        ("conv10_b", (NUM_CLASSES,)),
    ]
    return specs


def init_params(seed: int = SEED) -> dict[str, np.ndarray]:
    """He-initialized synthetic weights, small positive biases.

    Deterministic across runs: the Rust integration tests compare against
    goldens computed from exactly these values.
    """
    r = np.random.RandomState(seed)
    params: dict[str, np.ndarray] = {}
    for name, shape in param_specs():
        if name.endswith("_b"):
            params[name] = (r.uniform(0.0, 0.01, shape)).astype(np.float32)
        else:
            fan_in = int(np.prod(shape[:-1]))
            std = float(np.sqrt(2.0 / fan_in))
            params[name] = (r.randn(*shape) * std).astype(np.float32)
    return params


# ---------------------------------------------------------------------------
# Stage functions (the ACL engine's unit of execution)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Stage:
    """One fused ACL-engine stage.

    `param_names` is the stage's slice of the global parameter table, in
    call order; `fn(params_list, x)` is the jax function that lowers to the
    stage's HLO executable.
    """
    index: int
    name: str
    param_names: tuple[str, ...]
    in_shape: tuple[int, ...]   # HWC (no batch)
    out_shape: tuple[int, ...]  # HWC or (C,) for the head
    fn: Callable

    def jit_args(self, batch: int):
        """Example args for jax.jit(...).lower."""
        f32 = jnp.float32
        params = [jax.ShapeDtypeStruct(_shape_of(p), f32)
                  for p in self.param_names]
        x = jax.ShapeDtypeStruct((batch, *self.in_shape), f32)
        return params, x


_SHAPES = dict(param_specs())


def _shape_of(name: str) -> tuple[int, ...]:
    return _SHAPES[name]


def _conv1_stage(params, x):
    w, b = params
    y = kernels.conv2d(x, w, b, stride=2, padding="VALID", activation="relu")
    return kernels.maxpool2d(y, window=3, stride=2)


def _make_fire_stage(f: FireSpec, pool: bool):
    def fn(params, x):
        ws, bs, w1, b1, w3, b3 = params
        y = kernels.fire(x, ws, bs, w1, b1, w3, b3)
        if pool:
            y = kernels.maxpool2d(y, window=3, stride=2)
        return y
    return fn


def _head_stage(params, x):
    w, b = params
    y = kernels.pointwise_conv(x, w, b, activation="relu")
    pooled = kernels.global_avgpool(y, attenuation=ATTENUATION)
    return kernels.softmax(pooled)


def _spatial_ladder() -> dict[str, int]:
    """H(=W) of each stage's input, following the v1.0 ladder."""
    return {
        "conv1": 227, "fire2": 55, "fire3": 55, "fire4": 55,
        "fire5": 27, "fire6": 27, "fire7": 27, "fire8": 27,
        "fire9": 13, "head": 13,
    }


def stages() -> list[Stage]:
    """The ACL engine's stage list, in execution order."""
    hw = _spatial_ladder()
    out: list[Stage] = [Stage(
        index=0, name="conv1",
        param_names=("conv1_w", "conv1_b"),
        in_shape=(227, 227, 3), out_shape=(55, 55, 96),
        fn=_conv1_stage,
    )]
    for f in FIRES:
        pool = f.name in POOL_AFTER
        h = hw[f.name]
        h_out = (h - 3) // 2 + 1 if pool else h
        out.append(Stage(
            index=len(out), name=f.name,
            param_names=(f"{f.name}_sw", f"{f.name}_sb",
                         f"{f.name}_e1w", f"{f.name}_e1b",
                         f"{f.name}_e3w", f"{f.name}_e3b"),
            in_shape=(h, h, f.cin), out_shape=(h_out, h_out, f.cout),
            fn=_make_fire_stage(f, pool),
        ))
    out.append(Stage(
        index=len(out), name="head",
        param_names=("conv10_w", "conv10_b"),
        in_shape=(13, 13, 512), out_shape=(NUM_CLASSES,),
        fn=_head_stage,
    ))
    return out


# ---------------------------------------------------------------------------
# Whole-network forwards
# ---------------------------------------------------------------------------

def forward_fused(params: dict, x: jax.Array) -> jax.Array:
    """Whole network on the Pallas kernels (fully-fused artifact)."""
    for st in stages():
        plist = [params[p] for p in st.param_names]
        x = st.fn(plist, x)
    return x


def forward_ref(params: dict, x: jax.Array) -> jax.Array:
    """Whole network on the pure-jnp oracle ops (goldens/calibration)."""
    y = ref.conv2d(x, params["conv1_w"], params["conv1_b"], stride=2,
                   activation="relu")
    y = ref.maxpool2d(y)
    for f in FIRES:
        y = ref.fire(y, params[f"{f.name}_sw"], params[f"{f.name}_sb"],
                     params[f"{f.name}_e1w"], params[f"{f.name}_e1b"],
                     params[f"{f.name}_e3w"], params[f"{f.name}_e3b"])
        if f.name in POOL_AFTER:
            y = ref.maxpool2d(y)
    y = ref.conv2d(y, params["conv10_w"], params["conv10_b"],
                   activation="relu")
    y = ref.global_avgpool(y, attenuation=ATTENUATION)
    return ref.softmax(y)


def activation_sites(params: dict, x: jax.Array) -> dict[str, jax.Array]:
    """Named intermediate activations on the oracle path.

    Used for (a) quantization calibration (per-conv-input scales) and
    (b) per-stage goldens for the Rust integration tests.
    """
    acts: dict[str, jax.Array] = {"input": x}
    y = ref.conv2d(x, params["conv1_w"], params["conv1_b"], stride=2,
                   activation="relu")
    y = ref.maxpool2d(y)
    acts["conv1"] = y
    for f in FIRES:
        acts[f"{f.name}_in"] = y
        s = ref.conv2d(y, params[f"{f.name}_sw"], params[f"{f.name}_sb"],
                       activation="relu")
        acts[f"{f.name}_squeeze"] = s
        y = ref.fire(y, params[f"{f.name}_sw"], params[f"{f.name}_sb"],
                     params[f"{f.name}_e1w"], params[f"{f.name}_e1b"],
                     params[f"{f.name}_e3w"], params[f"{f.name}_e3b"])
        if f.name in POOL_AFTER:
            y = ref.maxpool2d(y)
        acts[f.name] = y
    acts["conv10_in"] = y
    y = ref.conv2d(y, params["conv10_w"], params["conv10_b"],
                   activation="relu")
    y = ref.global_avgpool(y, attenuation=ATTENUATION)
    acts["pooled"] = y
    acts["probs"] = ref.softmax(y)
    return acts


# ---------------------------------------------------------------------------
# Probe stages (Fig 3 group-breakdown granularity)
# ---------------------------------------------------------------------------

def _probe_conv1(params, x):
    w, b = params
    return kernels.conv2d(x, w, b, stride=2, padding="VALID",
                          activation="relu")


def _probe_pool(params, x):
    del params
    return kernels.maxpool2d(x, window=3, stride=2)


def _make_probe_fire(f: FireSpec):
    def fn(params, x):
        ws, bs, w1, b1, w3, b3 = params
        return kernels.fire(x, ws, bs, w1, b1, w3, b3)
    return fn


def _probe_conv10(params, x):
    w, b = params
    return kernels.pointwise_conv(x, w, b, activation="relu")


def _probe_gap(params, x):
    del params
    return kernels.global_avgpool(x, attenuation=ATTENUATION)


def _probe_softmax(params, x):
    del params
    return kernels.softmax(x)


# Fig 3 group classification for probe stages.
PROBE_GROUPS = {
    "conv1": "group1", "pool1": "group2",
    **{f.name: "group1" for f in FIRES},
    "pool4": "group2", "pool8": "group2",
    "conv10": "group1", "gap": "group2", "softmax": "group2",
}


def probe_stages() -> list[Stage]:
    """Finer-grained ACL stage list used only by the Fig 3 breakdown bench.

    Same kernels and fusion *within* group-1 blocks (fire modules stay
    fused, conv+relu stays fused), but pools / gap / softmax are separate
    executables so the ledger can attribute time to group 1 vs group 2 for
    the ACL engine, matching the paper's instrumentation.
    """
    out: list[Stage] = [Stage(0, "conv1", ("conv1_w", "conv1_b"),
                              (227, 227, 3), (111, 111, 96), _probe_conv1)]
    out.append(Stage(1, "pool1", (), (111, 111, 96), (55, 55, 96),
                     _probe_pool))
    hw = _spatial_ladder()
    for f in FIRES:
        h = hw[f.name]
        out.append(Stage(len(out), f.name,
                         (f"{f.name}_sw", f"{f.name}_sb",
                          f"{f.name}_e1w", f"{f.name}_e1b",
                          f"{f.name}_e3w", f"{f.name}_e3b"),
                         (h, h, f.cin), (h, h, f.cout),
                         _make_probe_fire(f)))
        if f.name in POOL_AFTER:
            hp = (h - 3) // 2 + 1
            out.append(Stage(len(out), f"pool{f.name[-1]}", (),
                             (h, h, f.cout), (hp, hp, f.cout), _probe_pool))
    out.append(Stage(len(out), "conv10", ("conv10_w", "conv10_b"),
                     (13, 13, 512), (13, 13, NUM_CLASSES), _probe_conv10))
    out.append(Stage(len(out), "gap", (), (13, 13, NUM_CLASSES),
                     (NUM_CLASSES,), _probe_gap))
    out.append(Stage(len(out), "softmax", (), (NUM_CLASSES,),
                     (NUM_CLASSES,), _probe_softmax))
    return out
