"""AOT pipeline: lower every artifact the Rust runtime loads.

Run once via `make artifacts` (python -m compile.aot --out ../artifacts).
Python never runs again after this; the Rust binary is self-contained.

Outputs (see DESIGN.md §4):
    manifest.json            — global metadata: param tables, stages, ops,
                               scales, golden index.  The Rust coordinator's
                               single source of truth.
    weights.bin              — all fp32 params, little-endian, manifest order.
    weights_q8.bin           — int8 conv weights (+ scales in manifest).
    golden/*.bin             — deterministic input + oracle outputs for the
                               Rust integration tests.
    acl/stage_*.hlo.txt      — fused per-stage executables (batch variants).
    acl/probe_*.hlo.txt      — finer-grained stages for the Fig 3 breakdown.
    acl/full_*.hlo.txt       — fully-fused whole network (ablation + serving).
    tf/op_*.hlo.txt          — one executable per baseline-graph op.
    quant/op_*.hlo.txt       — one executable per quantized-graph op (Fig 4).

Interchange format is HLO **text** (not serialized HloModuleProto): jax
>= 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import graph, model, quantize

BATCH_SIZES = (1, 2, 4, 8)
GOLDEN_SEED = 123


# ---------------------------------------------------------------------------
# Lowering helpers
# ---------------------------------------------------------------------------

def to_hlo_text(fn, args) -> str:
    """jit-lower `fn` at `args` (ShapeDtypeStructs) and emit HLO text.

    `return_tuple=True` so the Rust side can uniformly `to_tuple1()`.
    """
    lowered = jax.jit(fn).lower(*args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _sds(shape, dtype="f32"):
    return jax.ShapeDtypeStruct(
        tuple(shape), jnp.int8 if dtype == "i8" else jnp.float32)


def _write(path: str, text: str) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text)


def _write_bin(path: str, arr: np.ndarray) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    arr.tofile(path)


class _Progress:
    def __init__(self, label: str, total: int):
        self.label, self.total, self.done = label, total, 0
        self.t0 = time.time()

    def tick(self, what: str) -> None:
        self.done += 1
        print(f"[aot] {self.label} {self.done}/{self.total} {what} "
              f"({time.time() - self.t0:.1f}s)", flush=True)


# ---------------------------------------------------------------------------
# Artifact builders
# ---------------------------------------------------------------------------

def write_weights(out: str, params: dict[str, np.ndarray]) -> list[dict]:
    """weights.bin + its manifest table (name/shape/offset in f32 elems)."""
    table, blobs, offset = [], [], 0
    for name, shape in model.param_specs():
        arr = np.ascontiguousarray(params[name], dtype="<f4")
        table.append({
            "name": name, "shape": list(shape), "dtype": "f32",
            "offset": offset, "nelems": int(arr.size),
        })
        blobs.append(arr.reshape(-1))
        offset += int(arr.size)
    _write_bin(os.path.join(out, "weights.bin"), np.concatenate(blobs))
    return table


def write_weights_q8(out: str, params: dict[str, np.ndarray]):
    """weights_q8.bin + table (int8 weights for the quantized graph)."""
    q8, w_scales = quantize.quantize_weights(params)
    table, blobs, offset = [], [], 0
    for conv, wname in quantize.CONV_WEIGHTS.items():
        arr = np.ascontiguousarray(q8[wname + "_q8"], dtype=np.int8)
        table.append({
            "name": wname + "_q8", "shape": list(arr.shape), "dtype": "i8",
            "offset": offset, "nelems": int(arr.size),
            "scale": float(w_scales[conv]),
        })
        blobs.append(arr.reshape(-1))
        offset += int(arr.size)
    _write_bin(os.path.join(out, "weights_q8.bin"), np.concatenate(blobs))
    return table, q8


def write_goldens(out: str, params, q8_params, scales) -> dict:
    """Deterministic input + oracle outputs for Rust integration tests."""
    r = np.random.RandomState(GOLDEN_SEED)
    img = r.uniform(-1.0, 1.0,
                    (1, model.INPUT_HW, model.INPUT_HW, 3)).astype(np.float32)
    _write_bin(os.path.join(out, "golden", "input.bin"), img)

    jparams = {k: jnp.asarray(v) for k, v in params.items()}
    sites = model.activation_sites(jparams, jnp.asarray(img))
    probs = np.asarray(sites["probs"])
    _write_bin(os.path.join(out, "golden", "probs.bin"), probs)

    stage_files = []
    for st in model.stages():
        key = st.name if st.name != "head" else "probs"
        arr = np.asarray(sites[key], dtype="<f4")
        fname = f"golden/stage_{st.index:02d}_{st.name}.bin"
        _write_bin(os.path.join(out, fname), arr)
        stage_files.append(fname)

    # Quantized-path golden via the graph reference interpreter.
    qops = graph.build_graph(quant=True)
    allp = {**jparams, **{k: jnp.asarray(v) for k, v in q8_params.items()}}
    env = graph.execute_graph(qops, allp, jnp.asarray(img), scales)
    probs_q8 = np.asarray(env["softmax"], dtype="<f4")
    _write_bin(os.path.join(out, "golden", "probs_q8.bin"), probs_q8)

    return {
        "input": "golden/input.bin",
        "probs": "golden/probs.bin",
        "probs_q8": "golden/probs_q8.bin",
        "stages": stage_files,
        "top1": int(np.argmax(probs[0])),
        "top1_q8": int(np.argmax(probs_q8[0])),
    }


def lower_stages(out: str, stages, kind: str, batch_sizes) -> list[dict]:
    """Lower a stage list (serving or probe) at each batch size."""
    prog = _Progress(kind, len(stages) * len(batch_sizes))
    entries = []
    for st in stages:
        artifacts = {}
        for b in batch_sizes:
            params, x = st.jit_args(b)
            fn = st.fn
            wrapper = (lambda f: lambda *a: f(list(a[:-1]), a[-1]))(fn)
            text = to_hlo_text(wrapper, [*params, x])
            rel = f"acl/{kind}_{st.index:02d}_{st.name}_b{b}.hlo.txt"
            _write(os.path.join(out, rel), text)
            artifacts[str(b)] = rel
            prog.tick(f"{st.name} b{b}")
        entries.append({
            "index": st.index, "name": st.name,
            "params": list(st.param_names),
            "in_shape": list(st.in_shape), "out_shape": list(st.out_shape),
            "group": model.PROBE_GROUPS.get(st.name, "group1")
            if kind == "probe" else None,
            "artifacts": artifacts,
        })
    return entries


def lower_full(out: str, batch_sizes) -> dict:
    """Fully-fused whole-network artifacts."""
    prog = _Progress("full", len(batch_sizes))
    artifacts = {}
    pspecs = [_sds(shape) for _, shape in model.param_specs()]
    names = [n for n, _ in model.param_specs()]

    def fn(*args):
        params = dict(zip(names, args[:-1]))
        return model.forward_fused(params, args[-1])

    for b in batch_sizes:
        x = _sds((b, model.INPUT_HW, model.INPUT_HW, 3))
        text = to_hlo_text(fn, [*pspecs, x])
        rel = f"acl/full_b{b}.hlo.txt"
        _write(os.path.join(out, rel), text)
        artifacts[str(b)] = rel
        prog.tick(f"full b{b}")
    return artifacts


def lower_ops(out: str, ops, scales, q8_table, prefix: str) -> list[dict]:
    """Lower one executable per graph op (batch 1)."""
    q8_shapes = {e["name"]: tuple(e["shape"]) for e in q8_table}
    prog = _Progress(prefix, len(ops))
    entries = []
    for op in ops:
        fn = graph.lower_fn(op, scales)
        args = []
        for p in op.param_names:
            if p.endswith("_q8"):
                args.append(_sds(q8_shapes[p], "i8"))
            else:
                args.append(_sds(model._shape_of(p)))
        for shp, dt in zip(op.in_shapes, op.in_dtypes):
            args.append(_sds((1, *shp), dt))
        text = to_hlo_text(fn, args)
        rel = f"{prefix}/op_{op.index:03d}_{op.name}.hlo.txt"
        _write(os.path.join(out, rel), text)
        prog.tick(op.name)
        entries.append({
            "index": op.index, "name": op.name, "kind": op.kind,
            "group": op.group, "inputs": list(op.inputs),
            "params": list(op.param_names),
            "in_shapes": [list(s) for s in op.in_shapes],
            "in_dtypes": list(op.in_dtypes),
            "out_shape": list(op.out_shape), "out_dtype": op.out_dtype,
            "artifact": rel,
        })
    return entries


# ---------------------------------------------------------------------------
# Main
# ---------------------------------------------------------------------------

def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="b1-only stages, no op graphs (dev loop)")
    args = ap.parse_args(argv)
    out = os.path.abspath(args.out)
    os.makedirs(out, exist_ok=True)
    t0 = time.time()

    print("[aot] init params + weights", flush=True)
    params = model.init_params()
    param_table = write_weights(out, params)
    q8_table, q8_params = write_weights_q8(out, params)

    print("[aot] calibration", flush=True)
    scales = quantize.calibrate(params)

    print("[aot] goldens", flush=True)
    golden = write_goldens(out, params, q8_params, scales)

    batch_sizes = (1,) if args.quick else BATCH_SIZES
    stage_entries = lower_stages(out, model.stages(), "stage", batch_sizes)
    probe_entries = lower_stages(out, model.probe_stages(), "probe", (1,))
    full_artifacts = lower_full(out, batch_sizes)

    if args.quick:
        op_entries, qop_entries = [], []
    else:
        op_entries = lower_ops(out, graph.build_graph(False), scales,
                               q8_table, "tf")
        qop_entries = lower_ops(out, graph.build_graph(True), scales,
                                q8_table, "quant")

    manifest = {
        "version": 1,
        "model": "squeezenet-v1.0",
        "input_hw": model.INPUT_HW,
        "input_channels": 3,
        "num_classes": model.NUM_CLASSES,
        "attenuation": model.ATTENUATION,
        "seed": model.SEED,
        "batch_sizes": list(batch_sizes),
        "weights_bin": "weights.bin",
        "weights_q8_bin": "weights_q8.bin",
        "params": param_table,
        "params_q8": q8_table,
        "scales": {k: float(v) for k, v in scales.items()},
        "stages": stage_entries,
        "probe_stages": probe_entries,
        "full": full_artifacts,
        "ops": op_entries,
        "quant_ops": qop_entries,
        "golden": golden,
    }
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] done in {time.time() - t0:.1f}s -> {out}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
