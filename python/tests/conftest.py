"""Shared hypothesis strategies for the kernel test suite.

Interpret-mode Pallas is slow, so shapes are kept small but *adversarial*:
odd sizes, tile sizes that do not divide the output, strides > 1, single-
row images — everything that has ever broken a tiled kernel.
"""

from __future__ import annotations

import numpy as np
from hypothesis import HealthCheck, settings
from hypothesis import strategies as st

# One profile for the whole suite: few examples, no deadline (XLA compile
# times dominate), suppress the too-slow health check for the same reason.
settings.register_profile(
    "kernels",
    max_examples=12,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
settings.load_profile("kernels")


def arrays(shape, seed, lo=-2.0, hi=2.0):
    """Deterministic float32 array for a shape + seed (hypothesis drives
    shapes/seeds; numpy generates values — cheaper to shrink than
    hypothesis-generated element lists)."""
    r = np.random.RandomState(seed % (2**31 - 1))
    return r.uniform(lo, hi, size=shape).astype(np.float32)


# Strategy pieces ----------------------------------------------------------

batches = st.integers(1, 3)
channels = st.integers(1, 8)
seeds = st.integers(0, 2**31 - 2)
row_tiles = st.integers(1, 9)


def spatial(min_size=1, max_size=14):
    return st.integers(min_size, max_size)
