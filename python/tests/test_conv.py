"""Hypothesis sweeps: Pallas conv kernels vs the pure-jnp oracle."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given
from hypothesis import strategies as st

from compile.kernels import common, conv2d, pointwise_conv, ref

from .conftest import arrays, batches, channels, row_tiles, seeds, spatial


@given(
    n=batches,
    h=spatial(5, 14),
    w=spatial(5, 14),
    cin=channels,
    cout=channels,
    k=st.sampled_from([1, 3, 5, 7]),
    stride=st.sampled_from([1, 2, 4]),
    padding=st.sampled_from(["VALID", "SAME", 1, 3]),
    act=st.sampled_from([None, "relu"]),
    tile=row_tiles,
    seed=seeds,
)
def test_conv2d_matches_ref(n, h, w, cin, cout, k, stride, padding, act,
                            tile, seed):
    plo, phi = common.resolve_padding(padding, k)
    if h + plo + phi < k or w + plo + phi < k:
        return  # empty output; constructor raises (covered below)
    x = jnp.asarray(arrays((n, h, w, cin), seed))
    wt = jnp.asarray(arrays((k, k, cin, cout), seed + 1))
    b = jnp.asarray(arrays((cout,), seed + 2))
    got = conv2d(x, wt, b, stride=stride, padding=padding, activation=act,
                 row_tile=tile)
    want = ref.conv2d(x, wt, b, stride=stride, padding=padding,
                      activation=act)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(
    n=batches, h=spatial(1, 10), w=spatial(1, 10), cin=channels,
    cout=channels, act=st.sampled_from([None, "relu"]),
    tile=st.integers(1, 64), seed=seeds,
)
def test_pointwise_matches_ref(n, h, w, cin, cout, act, tile, seed):
    x = jnp.asarray(arrays((n, h, w, cin), seed))
    wt = jnp.asarray(arrays((1, 1, cin, cout), seed + 1))
    b = jnp.asarray(arrays((cout,), seed + 2))
    got = pointwise_conv(x, wt, b, activation=act, row_tile=tile)
    want = ref.pointwise_conv(x, wt, b, activation=act)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(tile_a=row_tiles, tile_b=row_tiles, seed=seeds)
def test_conv2d_tiling_invariance(tile_a, tile_b, seed):
    """TH is a pure schedule knob: results agree across tile heights up
    to f32 accumulation-order tolerance (XLA dot blocking varies with M)."""
    x = jnp.asarray(arrays((1, 11, 9, 3), seed))
    w = jnp.asarray(arrays((3, 3, 3, 4), seed + 1))
    a = conv2d(x, w, stride=2, padding="SAME", row_tile=tile_a)
    b = conv2d(x, w, stride=2, padding="SAME", row_tile=tile_b)
    # Same accumulation-order tolerance as the fire invariance test.
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_conv2d_bias_default_is_zero():
    x = jnp.ones((1, 5, 5, 2), jnp.float32)
    w = jnp.ones((3, 3, 2, 2), jnp.float32)
    got = conv2d(x, w)
    want = ref.conv2d(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_conv2d_1x1_kernel_equals_pointwise():
    x = jnp.asarray(arrays((2, 6, 7, 3), 7))
    w = jnp.asarray(arrays((1, 1, 3, 5), 8))
    b = jnp.asarray(arrays((5,), 9))
    np.testing.assert_allclose(
        conv2d(x, w, b, activation="relu", row_tile=4),
        pointwise_conv(x, w, b, activation="relu"),
        rtol=1e-4, atol=1e-5,
    )


def test_conv2d_rejects_empty_output():
    x = jnp.ones((1, 3, 3, 1), jnp.float32)
    w = jnp.ones((7, 7, 1, 1), jnp.float32)
    with pytest.raises(ValueError, match="empty"):
        conv2d(x, w)


def test_conv2d_rejects_non_nhwc():
    with pytest.raises(ValueError, match="NHWC"):
        conv2d(jnp.ones((3, 3, 1), jnp.float32),
               jnp.ones((1, 1, 1, 1), jnp.float32))


def test_conv2d_squeezenet_conv1_shape():
    """The paper's first layer: 227x227x3, 7x7/s2 VALID, 96 filters."""
    x = jnp.zeros((1, 227, 227, 3), jnp.float32)
    w = jnp.zeros((7, 7, 3, 96), jnp.float32)
    out = conv2d(x, w, stride=2)
    assert out.shape == (1, 111, 111, 96)


def test_vmem_budget_largest_stage():
    """DESIGN.md §Perf: every conv tile must fit the 16 MiB VMEM budget."""
    # conv1 is the largest input tile: TH=8, W=227, Cin=3, k=7, s=2 -> W_out=111, Cout=96
    assert common.vmem_bytes_conv(8, 227, 3, 7, 2, 111, 96) < common.VMEM_BUDGET
    # fire8 expand3x3-equivalent worst case
    assert common.vmem_bytes_conv(8, 27, 64, 3, 1, 27, 256) < common.VMEM_BUDGET
