"""Model-level tests: architecture shapes, stage/graph consistency,
oracle-vs-kernel parity at network scale (small input variant for speed),
and quantization calibration sanity."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from compile import graph, model, quantize
from compile.kernels import ref


def test_param_specs_match_squeezenet_v10():
    specs = dict(model.param_specs())
    assert specs["conv1_w"] == (7, 7, 3, 96)
    assert specs["fire2_sw"] == (1, 1, 96, 16)
    assert specs["fire9_e3w"] == (3, 3, 64, 256)
    assert specs["conv10_w"] == (1, 1, 512, 1000)
    total = sum(int(np.prod(s)) for s in specs.values())
    # ~1.25M params, the paper's "50x fewer than AlexNet" SqueezeNet.
    assert 1_200_000 < total < 1_300_000


def test_init_params_deterministic():
    a = model.init_params()
    b = model.init_params()
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    c = model.init_params(seed=1)
    assert not np.array_equal(a["conv1_w"], c["conv1_w"])


def test_stage_shapes_chain():
    sts = model.stages()
    assert [s.name for s in sts][0] == "conv1"
    for prev, nxt in zip(sts, sts[1:]):
        assert prev.out_shape == nxt.in_shape, (prev.name, nxt.name)
    assert sts[-1].out_shape == (1000,)


def test_probe_stage_groups_cover_paper_classification():
    groups = {s.name: model.PROBE_GROUPS[s.name] for s in model.probe_stages()}
    assert groups["conv1"] == "group1"
    assert groups["fire5"] == "group1"
    assert groups["pool1"] == "group2"
    assert groups["softmax"] == "group2"
    assert groups["gap"] == "group2"


def test_forward_ref_output_is_distribution():
    params = {k: jnp.asarray(v) for k, v in model.init_params().items()}
    x = jnp.asarray(np.random.RandomState(0).uniform(
        -1, 1, (2, 227, 227, 3)).astype(np.float32))
    probs = model.forward_ref(params, x)
    assert probs.shape == (2, 1000)
    np.testing.assert_allclose(np.asarray(probs).sum(-1), [1.0, 1.0], rtol=1e-5)
    assert np.all(np.asarray(probs) >= 0)


def test_graph_matches_ref_forward():
    """The op-by-op baseline graph computes the same function as the
    monolithic oracle forward."""
    params = {k: jnp.asarray(v) for k, v in model.init_params().items()}
    x = jnp.asarray(np.random.RandomState(1).uniform(
        -1, 1, (1, 227, 227, 3)).astype(np.float32))
    want = model.forward_ref(params, x)
    ops = graph.build_graph(quant=False)
    env = graph.execute_graph(ops, params, x)
    np.testing.assert_allclose(np.asarray(env["softmax"]), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_quant_graph_close_to_fp32():
    params = model.init_params()
    jparams = {k: jnp.asarray(v) for k, v in params.items()}
    scales = quantize.calibrate(params)
    q8, _ = quantize.quantize_weights(params)
    allp = {**jparams, **{k: jnp.asarray(v) for k, v in q8.items()}}
    x = jnp.asarray(np.random.RandomState(2).uniform(
        -1, 1, (1, 227, 227, 3)).astype(np.float32))
    fp32 = model.forward_ref(jparams, x)
    env = graph.execute_graph(graph.build_graph(True), allp, x, scales)
    err = np.abs(np.asarray(env["softmax"]) - np.asarray(fp32)).max()
    assert err < 0.05, f"quantized probs drift {err}"
    assert np.argmax(env["softmax"]) == np.argmax(fp32)


def test_calibration_scales_complete_and_positive():
    params = model.init_params()
    scales = quantize.calibrate(params)
    convs = list(quantize.CONV_WEIGHTS)
    assert len(convs) == 26
    for c in convs:
        for suffix in (":in", ":w", ":deq"):
            assert scales[f"{c}{suffix}"] > 0
        np.testing.assert_allclose(
            scales[f"{c}:deq"], scales[f"{c}:in"] * scales[f"{c}:w"], rtol=1e-9)


def test_graph_counts():
    assert graph.graph_stats(graph.build_graph(False))["total"] == 66
    q = graph.graph_stats(graph.build_graph(True))
    assert q["total"] == 118
    assert q["quantize"] == q["conv_q8"] == q["dequant_bias"] == 26


def test_graph_is_topologically_ordered():
    for quant in (False, True):
        seen = {"input"}
        for op in graph.build_graph(quant):
            for i in op.inputs:
                assert i in seen, f"{op.name} uses {i} before production"
            seen.add(op.name)


def test_attenuation_matches_dropout_keep_prob():
    """Paper: dropout removed, compensated by attenuation after pool10.
    The coefficient must equal the keep probability (0.5)."""
    assert model.ATTENUATION == 0.5


def test_fused_forward_matches_ref_on_small_patch():
    """Kernel-composed forward vs oracle at full network depth.  Run on
    the real 227 input would take minutes in interpret mode; the stage
    chain is already covered by the Rust golden tests, so here we check
    a single fire+pool+head stack on a small spatial size."""
    r = np.random.RandomState(3)
    x = jnp.asarray(r.uniform(-1, 1, (1, 13, 13, 96)).astype(np.float32))
    p = {
        "sw": jnp.asarray(r.randn(1, 1, 96, 16).astype(np.float32) * 0.1),
        "sb": jnp.asarray(r.randn(16).astype(np.float32) * 0.01),
        "e1w": jnp.asarray(r.randn(1, 1, 16, 64).astype(np.float32) * 0.1),
        "e1b": jnp.asarray(r.randn(64).astype(np.float32) * 0.01),
        "e3w": jnp.asarray(r.randn(3, 3, 16, 64).astype(np.float32) * 0.1),
        "e3b": jnp.asarray(r.randn(64).astype(np.float32) * 0.01),
    }
    from compile import kernels
    got = kernels.fire(x, p["sw"], p["sb"], p["e1w"], p["e1b"], p["e3w"], p["e3b"])
    got = kernels.maxpool2d(got)
    want = ref.fire(x, p["sw"], p["sb"], p["e1w"], p["e1b"], p["e3w"], p["e3b"])
    want = ref.maxpool2d(want)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("batch", [1, 2])
def test_stage_fns_lower_without_error(batch):
    """Every serving stage must trace+lower at every batch size (the AOT
    pipeline's core operation)."""
    from compile.aot import to_hlo_text
    st = model.stages()[1]  # fire2 — representative
    params, x = st.jit_args(batch)
    wrapper = lambda *a: st.fn(list(a[:-1]), a[-1])  # noqa: E731
    text = to_hlo_text(wrapper, [*params, x])
    assert "HloModule" in text
    assert f"f32[{batch},55,55,128]" in text.replace(" ", "")
