"""Hypothesis sweeps: the fused fire-module kernel vs the oracle.

The oracle (`ref.fire`) uses an explicit concatenate; the kernel writes
channel slices.  Equality of the two proves the paper's concat-elimination
is a pure scheduling optimization.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
from hypothesis import given
from hypothesis import strategies as st

from compile.kernels import fire, ref

from .conftest import arrays, batches, row_tiles, seeds, spatial


def _fire_params(cin, s, e1, e3, seed):
    return dict(
        ws=jnp.asarray(arrays((1, 1, cin, s), seed)),
        bs=jnp.asarray(arrays((s,), seed + 1)),
        w1=jnp.asarray(arrays((1, 1, s, e1), seed + 2)),
        b1=jnp.asarray(arrays((e1,), seed + 3)),
        w3=jnp.asarray(arrays((3, 3, s, e3), seed + 4)),
        b3=jnp.asarray(arrays((e3,), seed + 5)),
    )


@given(
    n=batches,
    h=spatial(1, 12),
    w=spatial(3, 12),
    cin=st.integers(1, 8),
    s=st.integers(1, 6),
    e1=st.integers(1, 8),
    e3=st.integers(1, 8),
    tile=row_tiles,
    seed=seeds,
)
def test_fire_matches_ref(n, h, w, cin, s, e1, e3, tile, seed):
    x = jnp.asarray(arrays((n, h, w, cin), seed + 10))
    p = _fire_params(cin, s, e1, e3, seed)
    got = fire(x, p["ws"], p["bs"], p["w1"], p["b1"], p["w3"], p["b3"],
               row_tile=tile)
    want = ref.fire(x, p["ws"], p["bs"], p["w1"], p["b1"], p["w3"], p["b3"])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(tile_a=row_tiles, tile_b=row_tiles, seed=seeds)
def test_fire_tiling_invariance(tile_a, tile_b, seed):
    x = jnp.asarray(arrays((1, 11, 7, 4), seed + 10))
    p = _fire_params(4, 3, 5, 5, seed)
    a = fire(x, p["ws"], p["bs"], p["w1"], p["b1"], p["w3"], p["b3"],
             row_tile=tile_a)
    b = fire(x, p["ws"], p["bs"], p["w1"], p["b1"], p["w3"], p["b3"],
             row_tile=tile_b)
    # TH changes the matmul M-dimension, which changes XLA-CPU's dot
    # blocking and hence f32 accumulation order — tolerance, not equality.
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_fire_edge_rows_with_bias():
    """Regression guard for the halo-masking subtlety: squeeze(0-row) is
    relu(bias) != 0, so the kernel must mask *after* squeezing.  A large
    positive squeeze bias makes any corruption at the top/bottom rows
    obvious."""
    x = jnp.asarray(arrays((1, 5, 5, 3), 42))
    p = _fire_params(3, 2, 3, 3, 43)
    p["bs"] = p["bs"] + 100.0
    got = fire(x, p["ws"], p["bs"], p["w1"], p["b1"], p["w3"], p["b3"],
               row_tile=2)
    want = ref.fire(x, p["ws"], p["bs"], p["w1"], p["b1"], p["w3"], p["b3"])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


def test_fire_single_row_image():
    """H=1: both halo rows are masked; the 3x3 degenerates to one row."""
    x = jnp.asarray(arrays((2, 1, 6, 4), 7))
    p = _fire_params(4, 2, 3, 3, 8)
    got = fire(x, p["ws"], p["bs"], p["w1"], p["b1"], p["w3"], p["b3"])
    want = ref.fire(x, p["ws"], p["bs"], p["w1"], p["b1"], p["w3"], p["b3"])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_fire_squeezenet_fire2_shapes():
    """Paper fire2: 55x55x96 -> squeeze 16 -> expand 64+64 -> 55x55x128."""
    x = jnp.zeros((1, 55, 55, 96), jnp.float32)
    p = _fire_params(96, 16, 64, 64, 1)
    out = fire(x, p["ws"], p["bs"], p["w1"], p["b1"], p["w3"], p["b3"])
    assert out.shape == (1, 55, 55, 128)
