"""Hypothesis sweeps: int8 quantization kernels vs the oracle (Fig 4)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
from hypothesis import given
from hypothesis import strategies as st

from compile.kernels import conv2d, conv2d_q8, dequantize, quantize, ref

from .conftest import arrays, batches, channels, row_tiles, seeds, spatial


@given(
    shape=st.sampled_from([(9,), (3, 5), (2, 4, 3, 2)]),
    seed=seeds,
)
def test_quantize_dequantize_roundtrip_error_bound(shape, seed):
    """|x - dq(q(x))| <= scale/2 elementwise (symmetric rounding)."""
    x = jnp.asarray(arrays(shape, seed, lo=-3, hi=3))
    sc = ref.quant_scale(x)
    back = dequantize(quantize(x, sc), sc)
    err = np.abs(np.asarray(back) - np.asarray(x))
    assert err.max() <= sc / 2 + 1e-6


@given(shape=st.sampled_from([(16,), (4, 4)]), seed=seeds)
def test_quantize_matches_ref(shape, seed):
    x = jnp.asarray(arrays(shape, seed))
    sc = ref.quant_scale(x)
    np.testing.assert_array_equal(
        np.asarray(quantize(x, sc)), np.asarray(ref.quantize(x, sc)))


def test_quantize_saturates_at_127():
    x = jnp.asarray([1000.0, -1000.0, 0.0], jnp.float32)
    q = np.asarray(quantize(x, 1.0))
    np.testing.assert_array_equal(q, [127, -127, 0])


@given(
    n=batches, h=spatial(4, 10), w=spatial(4, 10), cin=channels,
    cout=channels, k=st.sampled_from([1, 3]), stride=st.sampled_from([1, 2]),
    padding=st.sampled_from(["VALID", "SAME"]), tile=row_tiles, seed=seeds,
)
def test_conv2d_q8_matches_ref(n, h, w, cin, cout, k, stride, padding, tile,
                               seed):
    x = jnp.asarray(arrays((n, h, w, cin), seed))
    wt = jnp.asarray(arrays((k, k, cin, cout), seed + 1))
    b = jnp.asarray(arrays((cout,), seed + 2))
    xs, wsc = ref.quant_scale(x), ref.quant_scale(wt)
    xq, wq = ref.quantize(x, xs), ref.quantize(wt, wsc)
    got = conv2d_q8(xq, wq, b, xs, wsc, stride=stride, padding=padding,
                    activation="relu", row_tile=tile)
    want = ref.conv2d_q8(xq, wq, b, xs, wsc, stride=stride, padding=padding,
                         activation="relu")
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(seed=seeds)
def test_q8_conv_approximates_f32_conv(seed):
    """End-to-end quantization error stays small relative to activation
    magnitude — the 'trade accuracy for performance' the paper accepts."""
    x = jnp.asarray(arrays((1, 8, 8, 4), seed))
    w = jnp.asarray(arrays((3, 3, 4, 6), seed + 1))
    xs, ws_ = ref.quant_scale(x), ref.quant_scale(w)
    q = conv2d_q8(ref.quantize(x, xs), ref.quantize(w, ws_), None, xs, ws_)
    f = conv2d(x, w)
    scale = np.abs(np.asarray(f)).max() + 1e-6
    rel = np.abs(np.asarray(q) - np.asarray(f)).max() / scale
    assert rel < 0.05, f"quantization error too large: {rel}"


def test_int32_accumulator_no_overflow_worst_case():
    """127*127*Cin*K*K for SqueezeNet's largest conv stays far below 2^31;
    the kernel's int32 accumulate is safe for every layer in the model."""
    worst = 127 * 127 * 512 * 3 * 3  # fire-expand worst case
    assert worst < 2**31 - 1
    # And empirically: all-max inputs through the kernel.
    x = jnp.full((1, 5, 5, 8), 127, jnp.int8)
    w = jnp.full((3, 3, 8, 4), 127, jnp.int8)
    out = conv2d_q8(x, w, None, 1.0, 1.0)
    np.testing.assert_allclose(
        np.asarray(out)[0, 1, 1, 0], 127.0 * 127.0 * 8 * 9, rtol=1e-6)
