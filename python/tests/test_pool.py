"""Hypothesis sweeps: pooling kernels vs the oracle."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given
from hypothesis import strategies as st

from compile.kernels import global_avgpool, maxpool2d, ref

from .conftest import arrays, batches, channels, row_tiles, seeds, spatial


@given(
    n=batches, h=spatial(3, 14), w=spatial(3, 14), c=channels,
    window=st.sampled_from([2, 3]), stride=st.sampled_from([1, 2, 3]),
    tile=row_tiles, seed=seeds,
)
def test_maxpool_matches_ref(n, h, w, c, window, stride, tile, seed):
    if h < window or w < window:
        return
    x = jnp.asarray(arrays((n, h, w, c), seed))
    got = maxpool2d(x, window=window, stride=stride, row_tile=tile)
    want = ref.maxpool2d(x, window=window, stride=stride)
    np.testing.assert_allclose(got, want, rtol=1e-6)


@given(tile_a=row_tiles, tile_b=row_tiles, seed=seeds)
def test_maxpool_tiling_invariance(tile_a, tile_b, seed):
    x = jnp.asarray(arrays((2, 13, 9, 4), seed))
    a = maxpool2d(x, row_tile=tile_a)
    b = maxpool2d(x, row_tile=tile_b)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_maxpool_negative_inputs_edge():
    """-inf tile-safety padding must never win a max, even when all real
    values are negative and the last tile is ragged."""
    x = -jnp.ones((1, 7, 7, 1), jnp.float32) * 5.0
    got = maxpool2d(x, window=3, stride=2, row_tile=2)
    np.testing.assert_allclose(got, ref.maxpool2d(x), rtol=0)
    assert np.all(np.isfinite(np.asarray(got)))


@given(
    n=batches, h=spatial(1, 12), w=spatial(1, 12), c=channels,
    atten=st.floats(0.05, 2.0), seed=seeds,
)
def test_global_avgpool_matches_ref(n, h, w, c, atten, seed):
    x = jnp.asarray(arrays((n, h, w, c), seed))
    got = global_avgpool(x, attenuation=atten)
    want = ref.global_avgpool(x, attenuation=atten)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_global_avgpool_attenuation_is_linear():
    """The dropout-compensation coefficient is a pure scale (paper Fig 2)."""
    x = jnp.asarray(arrays((1, 4, 4, 8), 3))
    base = np.asarray(global_avgpool(x, attenuation=1.0))
    half = np.asarray(global_avgpool(x, attenuation=0.5))
    np.testing.assert_allclose(half, base * 0.5, rtol=1e-6)


def test_maxpool_rejects_empty():
    with pytest.raises(ValueError, match="empty"):
        maxpool2d(jnp.ones((1, 2, 2, 1), jnp.float32), window=3, stride=2)


def test_maxpool_squeezenet_shapes():
    """All three SqueezeNet maxpool sites."""
    for h, expect in [(111, 55), (55, 27), (27, 13)]:
        x = jnp.zeros((1, h, h, 4), jnp.float32)
        assert maxpool2d(x).shape == (1, expect, expect, 4)
