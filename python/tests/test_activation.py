"""Hypothesis sweeps: activation / softmax / concat kernels vs the oracle."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
from hypothesis import given
from hypothesis import strategies as st

from compile.kernels import concat_channels, ref, relu, softmax

from .conftest import arrays, batches, channels, seeds, spatial


@given(
    shape=st.sampled_from([(7,), (3, 5), (2, 3, 4), (1, 5, 4, 3), (2, 227)]),
    tile=st.integers(1, 300),
    seed=seeds,
)
def test_relu_matches_ref_any_rank(shape, tile, seed):
    x = jnp.asarray(arrays(shape, seed))
    got = relu(x, row_tile=tile)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.relu(x)))


def test_relu_preserves_zero_and_sign():
    x = jnp.asarray([-1.0, -0.0, 0.0, 2.5], jnp.float32)
    np.testing.assert_array_equal(np.asarray(relu(x)), [0.0, 0.0, 0.0, 2.5])


@given(n=st.integers(1, 6), c=st.integers(1, 1000), seed=seeds)
def test_softmax_matches_ref(n, c, seed):
    x = jnp.asarray(arrays((n, c), seed, lo=-30, hi=30))
    got = softmax(x)
    want = ref.softmax(x)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)


def test_softmax_rows_sum_to_one():
    x = jnp.asarray(arrays((5, 1000), 11, lo=-50, hi=50))
    s = np.asarray(softmax(x)).sum(axis=-1)
    np.testing.assert_allclose(s, np.ones(5), rtol=1e-5)


def test_softmax_stable_at_large_logits():
    """Stability guard: huge logits must not produce NaN/Inf (the kernel
    subtracts the row max, like the paper's hand-written Soft-Max)."""
    x = jnp.asarray([[1e4, 1e4 - 1, 0.0]], jnp.float32)
    out = np.asarray(softmax(x))
    assert np.all(np.isfinite(out))
    np.testing.assert_allclose(out.sum(), 1.0, rtol=1e-6)


@given(
    n=batches, h=spatial(1, 8), w=spatial(1, 8),
    ca=channels, cb=channels, seed=seeds,
)
def test_concat_channels_matches_jnp(n, h, w, ca, cb, seed):
    a = jnp.asarray(arrays((n, h, w, ca), seed))
    b = jnp.asarray(arrays((n, h, w, cb), seed + 1))
    got = concat_channels(a, b)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(jnp.concatenate([a, b], axis=-1)))
