# Build-time entry points.  Python runs once here (L2 AOT lowering);
# it never touches the Rust request path.

.PHONY: artifacts artifacts-quick test-python test-rust

# Lower every engine variant to HLO artifacts + manifest + weights.
artifacts:
	cd python && python3 -m compile.aot --out ../artifacts

# Dev loop: batch-1 stages only, no op graphs.
artifacts-quick:
	cd python && python3 -m compile.aot --out ../artifacts --quick

test-python:
	cd python && python3 -m pytest tests -q

test-rust:
	cd rust && cargo test -q
