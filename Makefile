# Build-time entry points.  Python runs once here (L2 AOT lowering);
# it never touches the Rust request path.

.PHONY: artifacts artifacts-quick test-python test-rust bench-json bench-smoke

# Lower every engine variant to HLO artifacts + manifest + weights.
artifacts:
	cd python && python3 -m compile.aot --out ../artifacts

# Dev loop: batch-1 stages only, no op graphs.
artifacts-quick:
	cd python && python3 -m compile.aot --out ../artifacts --quick

test-python:
	cd python && python3 -m pytest tests -q

test-rust:
	cd rust && cargo test -q

# Perf trajectory: run the simulation benches (no artifacts needed) and
# emit BENCH_3.json (allocs/request, bytes/request, throughput, p50/p99).
bench-json:
	cd rust && cargo bench --bench hot_path_alloc -- --json ../BENCH_3.json
	cd rust && cargo bench --bench policy_slo -- --quick

# One-iteration smoke of the simulation benches (CI).
bench-smoke:
	cd rust && cargo bench --bench hot_path_alloc -- --quick
	cd rust && cargo bench --bench policy_slo -- --quick
