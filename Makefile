# Build-time entry points.  Python runs once here (L2 AOT lowering);
# it never touches the Rust request path.

.PHONY: artifacts artifacts-quick test-python test-rust bench-json \
        bench-smoke bench-baseline bench-gate stress stress-conn \
        stress-conn-ablation

# Lower every engine variant to HLO artifacts + manifest + weights.
artifacts:
	cd python && python3 -m compile.aot --out ../artifacts

# Dev loop: batch-1 stages only, no op graphs.
artifacts-quick:
	cd python && python3 -m compile.aot --out ../artifacts --quick

test-python:
	cd python && python3 -m pytest tests -q

test-rust:
	cd rust && cargo test -q

# Perf trajectory: run the simulation benches (no artifacts needed).
# $(BENCH_OUT) is this PR's headline trajectory (E17 AOT replica
# snapshots: snapshot-path construction >= 5x faster than a cold build,
# cold-model first-request p99 <= 2x warm p99 with snapshots + prefetch
# on, and a snapshots-off ablation that leaves the steady-state serving
# path unchanged — all self-gating in benches/replica_snapshot.rs);
# $(GATE_OUT) is the hot-path alloc trajectory the cross-PR regression
# gate compares against tools/bench_baseline.json.  Parameterized so
# each PR's trajectory file is explicit — a hardcoded name would
# silently clobber earlier trajectories.
BENCH_OUT ?= BENCH_10.json
GATE_OUT ?= bench_hot_path.json
TRACE_OUT ?= bench_trace_overhead.json
bench-json:
	cd rust && cargo bench --bench replica_snapshot -- --json ../$(BENCH_OUT)
	cd rust && cargo bench --bench hot_path_alloc -- --json ../$(GATE_OUT)
	cd rust && cargo bench --bench trace_overhead -- --json ../$(TRACE_OUT)
	cd rust && cargo bench --bench policy_slo -- --quick

# One-iteration smoke of the simulation benches (CI).
bench-smoke:
	cd rust && cargo bench --bench trace_overhead -- --quick
	cd rust && cargo bench --bench hot_path_alloc -- --quick
	cd rust && cargo bench --bench replica_snapshot -- --quick
	cd rust && cargo bench --bench policy_slo -- --quick

# Seed/refresh the committed perf baseline (run on a quiet machine).
bench-baseline:
	$(MAKE) bench-json GATE_OUT=tools/bench_baseline.json

# CI perf-regression gate: fail if the current trajectory regresses
# >20% vs the committed baseline.  GATE_FLAGS passes extra flags
# through (CI sets --require-baseline after self-seeding, so the gate
# is always enforcing there — see tools/bench_gate.rs).
GATE_FLAGS ?=
bench-gate:
	cd rust && cargo run --release --bin bench_gate -- \
		../tools/bench_baseline.json ../$(GATE_OUT) $(GATE_FLAGS)

# E12 local repro: skewed 3-model traffic against the sim engine on the
# shared worker runtime (asserts fixed thread count, zero losses, and
# bounded cold-model p99 — see EXPERIMENTS.md E12).
stress:
	cd rust && cargo run --release --example sched_stress

# E13 local repro: thousands of concurrent pipelined connections against
# the epoll reactor (asserts a fixed IO thread count, zero request loss,
# and overlapped in-flight requests — see EXPERIMENTS.md E13).
stress-conn:
	cd rust && cargo run --release --example conn_stress

# E13 A/B baseline: the same barrage against the thread-per-connection
# ablation plane (expect process threads ≈ connection count).
stress-conn-ablation:
	cd rust && cargo run --release --example conn_stress -- --conn-plane threads
