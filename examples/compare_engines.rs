//! Figure 3 driver: TF-baseline vs the from-scratch ACL engine.
//!
//! Regenerates all three panels of the paper's Fig 3 story on this
//! substrate: end-to-end latency, the group 1 / group 2 breakdown, and
//! CPU/RSS utilization.  Paper numbers for reference: TF 420 ms vs ACL
//! 320 ms (1.31x); group1 +23%, group2 +110%; TF 75% CPU / ~9 MB vs
//! ACL 90% CPU / ~10 MB.
//!
//! ```bash
//! cargo run --release --example compare_engines -- [iters]
//! ```

use anyhow::Result;
use std::time::Duration;

use zuluko::bench::{speedup_line, Bench, Stats};
use zuluko::engine::{build, EngineKind};
use zuluko::metrics::sysmon::Sysmon;
use zuluko::runtime::Manifest;
use zuluko::tensor::Tensor;

fn measure(
    kind: EngineKind,
    manifest: &Manifest,
    input: &Tensor,
    iters: usize,
) -> Result<(Stats, [f64; 4], f64, f64)> {
    let mut e = build(kind, manifest)?;
    e.warmup()?;
    e.ledger_mut().clear();

    let mon = Sysmon::start(Duration::from_millis(50));
    let stats = Bench::new(kind.as_str())
        .warmup(1)
        .iters(iters)
        .run(|| {
            e.infer(input).expect("infer");
        });
    let util = mon.stop()?;

    let groups = e.ledger().group_ms();
    let n = (iters + 1) as f64; // warmup iteration included in ledger
    let per_image = [groups[0] / n, groups[1] / n, groups[2] / n, groups[3] / n];
    Ok((stats, per_image, util.cpu_frac, util.avg_rss_mb))
}

fn main() -> Result<()> {
    let iters: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(10);
    let manifest = Manifest::load(&zuluko::artifacts_dir())?;
    let input = Tensor::random(&[1, 227, 227, 3], 7);

    println!("== Figure 3 reproduction (iters={iters}) ==\n");

    let (tf, tf_groups, tf_cpu, tf_rss) =
        measure(EngineKind::TfBaseline, &manifest, &input, iters)?;
    let (acl, _, acl_cpu, acl_rss) =
        measure(EngineKind::AclStaged, &manifest, &input, iters)?;
    let (aclf, _, _, _) = measure(EngineKind::AclFused, &manifest, &input, iters)?;
    // Probe granularity for the ACL group breakdown.
    let (_, acl_groups, _, _) =
        measure(EngineKind::AclProbe, &manifest, &input, iters)?;

    println!("-- Panel 1: end-to-end latency (ms/image) --");
    println!("{}", Stats::HEADER);
    for s in [&tf, &acl, &aclf] {
        println!("{}", s.row());
    }
    println!("{}", speedup_line(&tf, &acl));
    println!("{}", speedup_line(&tf, &aclf));
    println!("paper: TF 420 ms -> ACL 320 ms = 1.31x (25% speedup)\n");

    println!("-- Panel 2: group breakdown (ms/image, engine-attributed) --");
    println!("| group | tf | acl | speedup | paper |");
    println!("|---|---|---|---|---|");
    let g1 = (tf_groups[0], acl_groups[0]);
    let g2 = (tf_groups[1], acl_groups[1]);
    println!("| group1 conv/relu/concat | {:.1} | {:.1} | {:.2}x | 1.23x |",
             g1.0, g1.1, g1.0 / g1.1.max(1e-9));
    println!("| group2 pool/softmax | {:.1} | {:.1} | {:.2}x | 2.10x |",
             g2.0, g2.1, g2.0 / g2.1.max(1e-9));
    println!();

    println!("-- Panel 3: utilization --");
    println!("| engine | cpu % | rss MB | paper |");
    println!("|---|---|---|---|");
    println!("| tf  | {:.0}% | {:.0} | 75% / ~9 MB |", tf_cpu * 100.0, tf_rss);
    println!("| acl | {:.0}% | {:.0} | 90% / ~10 MB |", acl_cpu * 100.0, acl_rss);
    println!("\n(absolute RSS differs — XLA runtime vs bare ARM; the *ordering* is the claim)");
    Ok(())
}
