//! E12 — skewed-traffic scheduler stress (sim engine, no artifacts).
//!
//! Three registry models share the fixed worker runtime while traffic
//! is deliberately skewed: `hot` is saturated by closed-loop producers,
//! `warm` trickles, and `cold` sends occasional deadlined requests.
//! The run reports, per model, completed/p50/p99, plus worker occupancy
//! and the final thread accounting — the live demonstration of the
//! acceptance criteria:
//!
//! * total worker threads == the configured runtime size (not
//!   2 × models × workers), before *and* after a mid-run hot reload;
//! * the reload drain loses no in-flight request;
//! * the cold model's p99 stays bounded (its deadlines hold) while the
//!   hot model saturates — weighted fair share + EDF override at work.
//!
//! Run: cargo run --release --example sched_stress [-- --quick]
//!      (or `make stress`)

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use zuluko::config::Config;
use zuluko::coordinator::{Coordinator, SubmitError};
use zuluko::engine::EngineKind;
use zuluko::policy::Slo;
use zuluko::tensor::Tensor;
use zuluko::util::percentile_sorted;

const HW: usize = 32;
const CLASSES: usize = 100;
const RUNTIME_WORKERS: usize = 2;
const COLD_DEADLINE_MS: f64 = 500.0;

fn model_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "zuluko_sched_stress_{tag}_{}",
        std::process::id()
    ));
    zuluko::testkit::manifest::write_synthetic(&dir, tag, CLASSES, HW, &[1, 2, 4])
        .unwrap();
    dir
}

fn zuluko_threads() -> usize {
    zuluko::testkit::sched::threads_named("zuluko-")
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let run_for = if quick {
        Duration::from_millis(800)
    } else {
        Duration::from_secs(3)
    };

    let mut cfg = Config {
        engine: EngineKind::Sim,
        workers: RUNTIME_WORKERS,
        max_batch: 4,
        batch_timeout: Duration::from_millis(2),
        queue_capacity: 32,
        ..Config::default()
    };
    for m in ["hot", "warm", "cold"] {
        cfg.registry.upsert(m, model_dir(m));
    }
    cfg.registry.default_model = Some("hot".to_string());
    cfg.registry.preload = true;
    // Skew the fair share too: cold is twice as important per byte of
    // backlog as hot — visible in the occupancy split under saturation.
    cfg.registry.set_weight("cold", 2.0);
    cfg.validate().unwrap();

    println!("== E12: skewed-traffic shared-runtime stress ==");
    println!(
        "3 sim models, runtime_workers={RUNTIME_WORKERS}, window {run_for:?}\n"
    );

    let coord = Arc::new(Coordinator::start(&cfg).unwrap());
    let threads_serving = zuluko_threads();
    println!(
        "threads: {threads_serving} zuluko threads for 3 models \
         (pre-runtime layout would hold {})",
        3 * RUNTIME_WORKERS
    );
    assert_eq!(
        threads_serving, RUNTIME_WORKERS,
        "worker threads must equal the configured runtime size"
    );

    type LatMap = std::collections::HashMap<&'static str, Vec<f64>>;
    let stop = Arc::new(AtomicBool::new(false));
    let lat: Arc<Mutex<LatMap>> = Arc::new(Mutex::new(LatMap::new()));
    let dropped = Arc::new(AtomicU64::new(0));

    let mut handles = Vec::new();
    // hot: 3 closed-loop saturating producers, best-effort.
    // warm: 1 producer with a small think time.
    // cold: 1 producer, deadlined, long think time.
    let roles: &[(&'static str, usize, u64, Option<f64>)] = &[
        ("hot", 3, 0, None),
        ("warm", 1, 3, None),
        ("cold", 1, 10, Some(COLD_DEADLINE_MS)),
    ];
    for &(model, producers, think_ms, deadline) in roles {
        for p in 0..producers {
            let coord = coord.clone();
            let stop = stop.clone();
            let lat = lat.clone();
            let dropped = dropped.clone();
            handles.push(std::thread::spawn(move || {
                let mut i = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let slo = match deadline {
                        Some(ms) => Slo::with_deadline_ms(ms),
                        None => Slo::default(),
                    };
                    let img = Tensor::random(&[HW, HW, 3], ((p as u64) << 32) | i);
                    i += 1;
                    match coord.submit_model(Some(model), img, slo) {
                        Ok(rx) => match rx.recv() {
                            Ok(r) if r.is_ok() => {
                                lat.lock().unwrap().entry(model).or_default().push(r.total_ms);
                            }
                            Ok(_) => {
                                dropped.fetch_add(1, Ordering::Relaxed);
                            }
                            Err(_) => {
                                dropped.fetch_add(1, Ordering::Relaxed);
                            }
                        },
                        Err(SubmitError::Overloaded) => std::thread::yield_now(),
                        // Reload race: the resolved generation retired
                        // between resolve and admit — re-resolve next
                        // iteration lands on the fresh one.
                        Err(SubmitError::Closed) => continue,
                        Err(e) => panic!("{model}: {e}"),
                    }
                    if think_ms > 0 {
                        std::thread::sleep(Duration::from_millis(think_ms));
                    }
                }
            }));
        }
    }

    // Mid-run: hot-reload the hot model under full pressure.  The drain
    // must not drop an in-flight request or grow the fleet.
    std::thread::sleep(run_for / 2);
    let report = coord.reload(Some("hot")).unwrap();
    println!(
        "mid-run reload: hot -> gen {} ({:.0}ms warm, under saturation)",
        report.generation, report.warm_ms
    );
    std::thread::sleep(run_for / 2);
    stop.store(true, Ordering::Relaxed);
    for h in handles {
        h.join().unwrap();
    }

    println!("\n| model | completed | p50 ms | p99 ms |");
    println!("|-------|-----------|--------|--------|");
    let lat = Arc::try_unwrap(lat).unwrap().into_inner().unwrap();
    let mut cold_p99 = 0.0;
    for &(model, ..) in roles {
        let mut xs = lat.get(model).cloned().unwrap_or_default();
        xs.sort_by(f64::total_cmp);
        let p50 = percentile_sorted(&xs, 50.0);
        let p99 = percentile_sorted(&xs, 99.0);
        if model == "cold" {
            cold_p99 = p99;
        }
        println!("| {model} | {} | {p50:.2} | {p99:.2} |", xs.len());
    }

    let stats = coord.stats();
    println!("\nworker occupancy:");
    for w in &stats.workers {
        println!(
            "  worker {}: batches={} images={} busy={:.0}%",
            w.worker,
            w.batches,
            w.images,
            w.busy_frac * 100.0
        );
    }
    println!("queue depths at stop:");
    for q in &stats.queues {
        println!(
            "  {}@g{}/{}: queued={} inflight={} weight={}",
            q.model, q.generation, q.engine, q.queued, q.inflight, q.weight
        );
    }

    // Let the reload drain settle, then check the acceptance criteria.
    let t0 = Instant::now();
    while zuluko_threads() > RUNTIME_WORKERS && t0.elapsed() < Duration::from_secs(5) {
        std::thread::sleep(Duration::from_millis(20));
    }
    let threads_after = zuluko_threads();
    let lost = dropped.load(Ordering::Relaxed);
    println!(
        "\nthreads after reload drain: {threads_after} (want {RUNTIME_WORKERS}) \
         | failed/dropped replies: {lost} | cold p99: {cold_p99:.2}ms \
         (deadline {COLD_DEADLINE_MS:.0}ms)"
    );
    assert_eq!(threads_after, RUNTIME_WORKERS, "reload drain grew the fleet");
    assert_eq!(lost, 0, "requests were lost under reload + saturation");
    assert!(
        cold_p99 > 0.0 && cold_p99 < COLD_DEADLINE_MS,
        "cold p99 {cold_p99:.2}ms not bounded — starvation"
    );
    println!("PASS: fixed fleet, zero losses, cold deadlines held.");

    match Arc::try_unwrap(coord) {
        Ok(c) => {
            c.shutdown();
        }
        Err(_) => panic!("coordinator still referenced"),
    }
}
