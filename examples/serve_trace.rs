//! End-to-end serving driver (E7) — the system-prompt's required demo.
//!
//! Boots the full stack (coordinator + dynamic batcher + TCP server +
//! ACL engine), replays a Poisson trace of synthetic camera frames over
//! real sockets, then a closed-loop run, and reports latency percentiles,
//! throughput, batch-size distribution, and utilization.
//!
//! ```bash
//! cargo run --release --example serve_trace -- [n_requests] [rate_rps]
//! ```

use anyhow::Result;
use std::sync::Arc;
use std::time::{Duration, Instant};

use zuluko::config::Config;
use zuluko::coordinator::Coordinator;
use zuluko::engine::EngineKind;
use zuluko::metrics::sysmon::Sysmon;
use zuluko::metrics::Histogram;
use zuluko::server::client::{Client, InferRequest};
use zuluko::server::Server;
use zuluko::trace::{Pattern, Trace};

fn main() -> Result<()> {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(40);
    let rate: f64 = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(6.0);

    let cfg = Config {
        engine: EngineKind::AclFused,
        workers: 1,
        max_batch: 8,
        batch_timeout: Duration::from_millis(40),
        queue_capacity: 64,
        ..Config::default()
    };

    println!("== E7: end-to-end serving (engine={}, n={n}, rate={rate}/s) ==",
             cfg.engine.as_str());
    let t0 = Instant::now();
    let coord = Arc::new(Coordinator::start(&cfg)?);
    println!("coordinator ready in {:.1}s (AOT load + XLA compile + warmup)",
             t0.elapsed().as_secs_f64());
    let server = Server::start(coord.clone(), "127.0.0.1:0")?;
    let addr = server.addr().to_string();

    // ---- open-loop Poisson replay over real TCP ----
    let trace = Trace::generate(Pattern::Poisson { rate }, n, 42);
    let mon = Sysmon::start(Duration::from_millis(100));
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for (i, (at, seed)) in trace
        .arrivals
        .iter()
        .zip(&trace.image_seeds)
        .enumerate()
    {
        let addr = addr.clone();
        let at = *at;
        let seed = *seed;
        let start = t0;
        handles.push(std::thread::spawn(move || {
            // Sleep until this request's arrival offset from trace start.
            std::thread::sleep(at.saturating_sub(start.elapsed()));
            let mut c = Client::connect(&addr).ok()?;
            c.infer(&InferRequest::new(i as u64).synthetic(seed)).ok()
        }));
    }
    let mut lat = Histogram::default();
    let mut batch_hist = Histogram::default();
    let mut ok = 0usize;
    let mut rejected = 0usize;
    for h in handles {
        match h.join().unwrap() {
            Some(r) if r.ok => {
                ok += 1;
                lat.record_ms(r.total_ms);
                batch_hist.record_ms(r.batch as f64);
            }
            Some(_) => rejected += 1,
            None => rejected += 1,
        }
    }
    let wall = t0.elapsed();
    let util = mon.stop()?;

    let (mean, p50, p95, p99, max) = lat.summary();
    println!("\n-- open-loop Poisson ({rate} rps offered) --");
    println!("| metric | value |");
    println!("|---|---|");
    println!("| completed | {ok}/{n} ({rejected} rejected) |");
    println!("| throughput | {:.2} img/s |", ok as f64 / wall.as_secs_f64());
    println!("| latency mean | {mean:.0} ms |");
    println!("| latency p50/p95/p99 | {p50:.0} / {p95:.0} / {p99:.0} ms |");
    println!("| latency max | {max:.0} ms |");
    println!("| mean batch size | {:.2} |", batch_hist.mean_ms());
    println!("| cpu | {:.0}% |", util.cpu_frac * 100.0);
    println!("| rss avg/peak | {:.0}/{:.0} MB |", util.avg_rss_mb, util.peak_rss_mb);

    // ---- closed-loop (the paper's own measurement mode), 1 client ----
    let m = 10.min(n);
    let mut c = Client::connect(&addr)?;
    let t0 = Instant::now();
    let mut closed = Histogram::default();
    for i in 0..m {
        let r = c.infer(&InferRequest::new(i as u64).synthetic(i as u64))?;
        anyhow::ensure!(r.ok, "closed-loop request failed: {:?}", r.error);
        closed.record_ms(r.total_ms);
    }
    let cwall = t0.elapsed();
    let (cmean, cp50, ..) = closed.summary();
    println!("\n-- closed-loop, 1 client ({} requests) --", m);
    println!("| latency mean/p50 | {cmean:.0} / {cp50:.0} ms |");
    println!("| throughput | {:.2} img/s |", m as f64 / cwall.as_secs_f64());

    let s = coord.stats();
    println!("\ncoordinator totals: completed={} rejected={} mean_batch={:.2}",
             s.completed, s.rejected, s.mean_batch);

    server.stop();
    Ok(())
}
