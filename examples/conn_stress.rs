//! E13 — connection-plane stress (sim engine, no artifacts).
//!
//! Holds ~1000+ concurrent pipelined connections against one server and
//! demonstrates the event plane's acceptance criteria live:
//!
//! * serving-side thread count is a small fixed constant (io_threads +
//!   acceptor + the worker runtime), independent of connection count —
//!   the pre-reactor plane needed one OS thread per connection and was
//!   hard-capped at 32 sockets;
//! * zero request loss: every request written gets exactly one reply
//!   with its own id echoed, across every connection;
//! * pipelining: requests per connection are written back-to-back
//!   before any reply is read, and the server's observed per-connection
//!   in-flight depth exceeds 1.
//!
//! `--conn-plane threads` runs the same barrage against the
//! thread-per-connection ablation baseline for the E13 A/B (expect the
//! process thread count to scale with connections).
//!
//! Run: cargo run --release --example conn_stress [-- --quick]
//!      (or `make stress-conn`; CI runs the --quick smoke)

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use zuluko::config::{Config, ConnPlane, ServerConfig};
use zuluko::coordinator::Coordinator;
use zuluko::engine::EngineKind;
use zuluko::server::{sys, Server};
use zuluko::testkit::sched::threads_named;
use zuluko::util::json::Json;

const HW: usize = 16;
const CLASSES: usize = 100;
const IO_THREADS: usize = 2;
const RUNTIME_WORKERS: usize = 2;
const DRIVERS: usize = 8;

fn model_dir() -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("zuluko_conn_stress_{}", std::process::id()));
    zuluko::testkit::manifest::write_synthetic(&dir, "m", CLASSES, HW, &[1, 2, 4])
        .unwrap();
    dir
}

fn process_threads() -> usize {
    std::fs::read_dir("/proc/self/task").map(|d| d.count()).unwrap_or(0)
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    let plane = match args.iter().position(|a| a == "--conn-plane") {
        Some(i) => ConnPlane::parse(args.get(i + 1).map(String::as_str).unwrap_or(""))
            .expect("--conn-plane event|threads"),
        None => ConnPlane::Event,
    };
    let (mut conns, reqs_per_conn) = if quick { (1000, 2) } else { (2000, 4) };

    // Each held connection costs two fds (client end + server end, same
    // process).  Raise RLIMIT_NOFILE and scale down if the hard limit
    // refuses — never fail the smoke over an environment cap.
    let want = (2 * conns + 512) as u64;
    match sys::raise_nofile_limit(want) {
        Ok(limit) if limit < want => {
            conns = ((limit.saturating_sub(512)) / 2) as usize;
            println!("fd limit {limit}: scaling down to {conns} connections");
        }
        Ok(_) => {}
        Err(e) => println!("raise_nofile_limit: {e} (continuing as-is)"),
    }

    let mut cfg = Config {
        engine: EngineKind::Sim,
        workers: RUNTIME_WORKERS,
        max_batch: 4,
        batch_timeout: Duration::from_millis(2),
        // The whole barrage is written before any reply is read, so the
        // admission queue must hold every in-flight request at once —
        // this run measures the connection plane, not shed behavior.
        queue_capacity: conns * reqs_per_conn,
        ..Config::default()
    };
    cfg.registry.upsert("m", model_dir());
    cfg.registry.default_model = Some("m".to_string());
    cfg.registry.preload = true;
    cfg.server = ServerConfig {
        conn_plane: plane,
        io_threads: IO_THREADS,
        max_connections: conns + 64,
        ..ServerConfig::default()
    };
    cfg.validate().unwrap();

    println!("== E13: connection-plane stress ==");
    println!(
        "plane={plane} conns={conns} reqs/conn={reqs_per_conn} \
         io_threads={IO_THREADS} runtime_workers={RUNTIME_WORKERS}\n"
    );

    let coord = Arc::new(Coordinator::start(&cfg).unwrap());
    let server = Server::start_with(coord.clone(), "127.0.0.1:0", &cfg.server).unwrap();
    let addr = server.addr();
    let threads_idle = process_threads();

    // Drivers connect their shard and write every request (pipelined:
    // no reply is read until all connections hold their full burst),
    // then park at the barrier so main can observe the peak.
    let hold = Arc::new(Barrier::new(DRIVERS + 1));
    let go_read = Arc::new(Barrier::new(DRIVERS + 1));
    let lost = Arc::new(AtomicU64::new(0));
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for d in 0..DRIVERS {
        let shard = conns / DRIVERS + usize::from(d < conns % DRIVERS);
        let (hold, go_read, lost) = (hold.clone(), go_read.clone(), lost.clone());
        handles.push(std::thread::spawn(move || {
            let mut held = Vec::with_capacity(shard);
            for c in 0..shard {
                let stream = TcpStream::connect(addr).expect("connect");
                stream
                    .set_read_timeout(Some(Duration::from_secs(60)))
                    .unwrap();
                let mut w = stream.try_clone().unwrap();
                let mut burst = String::new();
                for k in 0..reqs_per_conn {
                    let seed = ((d as u64) << 40) | ((c as u64) << 8) | k as u64;
                    burst.push_str(&format!(
                        "{{\"id\":{k},\"image\":{{\"synthetic\":{seed}}}}}\n"
                    ));
                }
                w.write_all(burst.as_bytes()).expect("write burst");
                held.push(BufReader::new(stream));
            }
            hold.wait();
            go_read.wait();
            // Collect replies: every id 0..reqs_per_conn exactly once.
            for reader in &mut held {
                let mut seen = vec![false; reqs_per_conn];
                for _ in 0..reqs_per_conn {
                    let mut line = String::new();
                    match reader.read_line(&mut line) {
                        Ok(n) if n > 0 => {}
                        _ => {
                            lost.fetch_add(1, Ordering::Relaxed);
                            continue;
                        }
                    }
                    let ok = Json::parse(&line)
                        .ok()
                        .filter(|j| {
                            j.get("ok").and_then(|v| v.as_bool()) == Some(true)
                        })
                        .and_then(|j| j.usize_of("id").ok())
                        .filter(|&id| id < reqs_per_conn && !seen[id]);
                    match ok {
                        Some(id) => seen[id] = true,
                        None => {
                            lost.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            }
        }));
    }

    // Peak: every connection open and loaded, before any reply drains.
    hold.wait();
    let deadline = Instant::now() + Duration::from_secs(30);
    while server.conn_snapshot().connections < conns && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(10));
    }
    let peak = server.conn_snapshot();
    let io = threads_named("zuluko-io-");
    let acceptors = threads_named("zuluko-accept");
    let threads_peak = process_threads();
    println!(
        "peak: {} connections held | zuluko-io threads: {io} | \
         acceptors: {acceptors} | process threads: {threads_idle} idle -> \
         {threads_peak} loaded",
        peak.connections
    );
    go_read.wait();

    for h in handles {
        h.join().unwrap();
    }
    let wall = t0.elapsed();
    let total = (conns * reqs_per_conn) as u64;
    let final_snap = server.conn_snapshot();
    let lost = lost.load(Ordering::Relaxed);
    println!(
        "\n{total} requests over {conns} conns in {:.2}s ({:.0} req/s) | \
         lost: {lost} | peak per-conn in-flight: {} | backpressure pauses: {}",
        wall.as_secs_f64(),
        total as f64 / wall.as_secs_f64(),
        final_snap.peak_conn_in_flight,
        final_snap.backpressure_events,
    );

    assert_eq!(lost, 0, "request loss under connection stress");
    assert_eq!(peak.connections, conns, "not all connections were admitted");
    if plane == ConnPlane::Event {
        assert_eq!(
            io, IO_THREADS,
            "event plane IO fleet must stay fixed under load"
        );
        assert!(
            threads_peak < threads_idle + 16,
            "event plane grew threads with connections \
             ({threads_idle} -> {threads_peak} for {conns} conns)"
        );
        assert!(
            final_snap.peak_conn_in_flight >= 2,
            "pipelining never overlapped requests in flight"
        );
        println!(
            "PASS: {conns} conns on {IO_THREADS} io threads, zero loss, \
             pipelining verified."
        );
    } else {
        println!(
            "PASS (ablation): threads plane served {conns} conns with zero \
             loss using ~1 thread per connection ({threads_peak} process \
             threads at peak vs {threads_idle} idle)."
        );
    }

    server.stop();
    let mut coord = coord;
    let coord = loop {
        match Arc::try_unwrap(coord) {
            Ok(c) => break c,
            Err(arc) => {
                coord = arc;
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    };
    coord.shutdown();
}
