//! SLO-aware serving demo (E9) — the policy layer end-to-end over TCP.
//!
//! Boots the adaptive coordinator (fp32 pool + int8 quant pool + response
//! cache), then walks the whole policy surface with a real client:
//!
//! 1. a deadline-tagged request (`deadline_ms` + `priority` on the wire)
//!    round-trips and reports which engine served it;
//! 2. the *same* frame again hits the response cache (`"cached":true`,
//!    `"engine":"cache"`) without touching an engine;
//! 3. an impossible deadline is shed at admission with a structured
//!    `"kind":"shed"` rejection carrying the prediction that doomed it;
//! 4. `{"cmd":"policy"}` exposes per-pool predictions, cache stats, and
//!    shed counters.
//!
//! ```bash
//! cargo run --release --example slo_serve
//! ```

use anyhow::Result;
use std::sync::Arc;
use std::time::{Duration, Instant};

use zuluko::config::Config;
use zuluko::coordinator::Coordinator;
use zuluko::engine::EngineKind;
use zuluko::server::client::{Client, InferRequest};
use zuluko::server::Server;

fn main() -> Result<()> {
    let dir = zuluko::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP slo_serve: run `make artifacts` first");
        return Ok(());
    }

    let mut cfg = Config {
        engine: EngineKind::AclFused,
        workers: 1,
        max_batch: 4,
        batch_timeout: Duration::from_millis(25),
        queue_capacity: 32,
        ..Config::default()
    };
    cfg.policy.adaptive = true;
    cfg.policy.quant_workers = 1;
    cfg.policy.cache_capacity = 64;

    println!("== E9: SLO-aware serving (adaptive={}, cache={}) ==",
             cfg.policy.adaptive, cfg.policy.cache_capacity);
    let t0 = Instant::now();
    let coord = Arc::new(Coordinator::start(&cfg)?);
    println!("coordinator ready in {:.1}s (both pools compiled + warm)",
             t0.elapsed().as_secs_f64());
    let server = Server::start(coord.clone(), "127.0.0.1:0")?;
    let mut c = Client::connect(&server.addr().to_string())?;

    // 1. Deadline-tagged request over the wire.
    let r = c.infer(&InferRequest::new(1).synthetic(12345).deadline_ms(60_000.0).priority("hi"))?;
    anyhow::ensure!(r.ok, "deadline-tagged request failed: {:?}", r.error);
    println!("\n#1 deadline=60000ms priority=hi -> ok, engine={} total={:.0}ms \
              top1={}", r.engine, r.total_ms, r.top1);
    anyhow::ensure!(!r.cached, "first frame must be a cold inference");

    // 2. The same frame again: served from the response cache.
    let r2 = c.infer(&InferRequest::new(2).synthetic(12345).deadline_ms(60_000.0))?;
    anyhow::ensure!(r2.ok, "repeat frame failed: {:?}", r2.error);
    anyhow::ensure!(
        r2.cached && r2.engine == "cache",
        "expected a cache hit, got engine={} cached={}", r2.engine, r2.cached
    );
    anyhow::ensure!(r2.top1 == r.top1, "cache hit changed the answer");
    println!("#2 same frame        -> cache hit, total={:.2}ms (cold was \
              {:.0}ms), identical top1={}", r2.total_ms, r.total_ms, r2.top1);

    // 3. An impossible deadline: structured shed, no engine time burned.
    let r3 = c.infer(&InferRequest::new(3).synthetic(999).deadline_ms(1.0))?;
    anyhow::ensure!(!r3.ok, "1ms deadline should not be servable");
    anyhow::ensure!(
        r3.kind.as_deref() == Some("shed"),
        "expected kind=shed, got {:?} ({:?})", r3.kind, r3.error
    );
    println!("#3 deadline=1ms      -> shed at admission: {}",
             r3.error.as_deref().unwrap_or(""));

    // 4. Policy introspection.
    let p = c.policy()?;
    println!("\n{{\"cmd\":\"policy\"}} ->");
    if let Some(pools) = p.get("pools").and_then(|v| v.as_arr()) {
        println!("| pool | workers | queued | predicted ms | samples |");
        println!("|---|---|---|---|---|");
        for pool in pools {
            println!(
                "| {} | {} | {} | {:.0} | {} |",
                pool.str_of("engine").unwrap_or("?"),
                pool.usize_of("workers").unwrap_or(0),
                pool.usize_of("queued").unwrap_or(0),
                pool.f64_of("predicted_ms").unwrap_or(0.0),
                pool.usize_of("samples").unwrap_or(0),
            );
        }
    }
    if let Some(cache) = p.get("cache") {
        println!(
            "cache: {}h/{}m len={} cap={}",
            cache.usize_of("hits").unwrap_or(0),
            cache.usize_of("misses").unwrap_or(0),
            cache.usize_of("len").unwrap_or(0),
            cache.usize_of("capacity").unwrap_or(0),
        );
    }
    println!(
        "shed_predicted={} shed_expired={}",
        p.usize_of("shed_predicted").unwrap_or(0),
        p.usize_of("shed_expired").unwrap_or(0),
    );

    let s = coord.stats();
    anyhow::ensure!(s.cache_hits >= 1, "stats should count the cache hit");
    anyhow::ensure!(s.shed_predicted >= 1, "stats should count the shed");
    println!("\nall policy paths exercised: route, cache hit, structured shed.");

    server.stop();
    Ok(())
}
