//! Observability plane demo (DESIGN.md §10) — request-lifecycle tracing
//! and the unified metrics export, end-to-end over TCP on the sim
//! engine (no artifacts needed):
//!
//! 1. serve a burst with `--trace-sample-rate 1.0`: every request's
//!    eight-stage timeline is retained in the lock-free trace rings;
//! 2. `{"cmd":"metrics"}` returns one line merging every subsystem —
//!    per-stage latency histograms, trace counters, conn plane, process
//!    health (`"proc"` from /proc);
//! 3. `{"cmd":"trace","n":K}` returns the last K timelines with
//!    ms-offset marks and classification flags;
//! 4. an impossible deadline is shed at admission and lands in the
//!    always-capture slow log with a `shed_predicted` flag — anomalies
//!    are retained even when sampling would have dropped them.
//!
//! ```bash
//! cargo run --release --example obs_demo
//! ```

use anyhow::Result;
use std::sync::Arc;
use std::time::Duration;

use zuluko::config::Config;
use zuluko::coordinator::Coordinator;
use zuluko::engine::EngineKind;
use zuluko::obs::STAGE_NAMES;
use zuluko::server::client::{Client, InferRequest};
use zuluko::server::Server;
use zuluko::util::json::Json;

const MODEL: &str = "demo";
const HW: usize = 64;

fn print_span(span: &Json) {
    let Some(marks) = span.get("marks") else {
        return;
    };
    let flags = span
        .get("flags")
        .and_then(|v| v.as_arr())
        .map(|fs| {
            fs.iter()
                .filter_map(|f| f.as_str())
                .collect::<Vec<_>>()
                .join(",")
        })
        .unwrap_or_default();
    let timeline = STAGE_NAMES
        .iter()
        .filter_map(|name| marks.f64_of(name).ok().map(|v| format!("{name}@{v:.3}")))
        .collect::<Vec<_>>()
        .join(" → ");
    println!(
        "  id={} total={:.3}ms [{}]\n    {}",
        span.usize_of("id").unwrap_or(0),
        span.f64_of("total_ms").unwrap_or(0.0),
        flags,
        timeline
    );
}

fn main() -> Result<()> {
    // A synthetic sim model: runnable on any machine, CI included.
    let dir = std::env::temp_dir().join(format!("zuluko_obs_demo_{}", std::process::id()));
    zuluko::testkit::manifest::write_synthetic(&dir, MODEL, 100, HW, &[1, 2, 4])?;
    let mut cfg = Config {
        engine: EngineKind::Sim,
        workers: 2,
        max_batch: 4,
        batch_timeout: Duration::from_millis(2),
        queue_capacity: 64,
        ..Config::default()
    };
    cfg.registry.upsert(MODEL, dir);
    cfg.registry.default_model = Some(MODEL.to_string());
    // Retain every timeline for the demo (production default is 0.01),
    // and enable the response cache so a repeat frame shows a
    // `cache_hit` timeline.
    cfg.obs.trace_sample_rate = 1.0;
    cfg.policy.cache_capacity = 64;
    cfg.validate()?;

    println!(
        "== observability demo (sample rate {}, ring {}, slow log {}) ==",
        cfg.obs.trace_sample_rate, cfg.obs.trace_ring, cfg.obs.slow_log
    );
    let coord = Arc::new(Coordinator::start(&cfg)?);
    let server = Server::start_with(coord.clone(), "127.0.0.1:0", &cfg.server)?;
    let mut c = Client::connect(&server.addr().to_string())?;

    // 1. A traced burst (distinct frames), plus one repeat for a
    //    cache-hit timeline.
    const N: u64 = 24;
    for i in 0..N {
        let r = c.infer(&InferRequest::new(i).synthetic(9000 + i))?;
        anyhow::ensure!(r.ok, "request {i} failed: {:?}", r.error);
    }
    let hit = c.infer(&InferRequest::new(N).synthetic(9000))?;
    anyhow::ensure!(hit.ok && hit.cached, "repeat frame should hit the cache");

    // 2. An impossible deadline: shed at admission, always captured.
    let shed = c.infer(&InferRequest::new(N + 1).synthetic(31337).deadline_ms(0.05))?;
    anyhow::ensure!(!shed.ok, "a 50µs deadline should be shed");
    println!(
        "\nshed request -> kind={:?} ({})",
        shed.kind,
        shed.error.as_deref().unwrap_or("")
    );

    // 3. The unified metrics line.
    let m = c.metrics()?;
    println!("\n{{\"cmd\":\"metrics\"}} ->");
    if let Some(stages) = m.get("stages").and_then(|v| v.as_arr()) {
        println!("| stage | count | p50 ms | p99 ms |");
        println!("|---|---|---|---|");
        for row in stages {
            println!(
                "| {} | {} | {:.3} | {:.3} |",
                row.str_of("stage").unwrap_or("?"),
                row.usize_of("count").unwrap_or(0),
                row.f64_of("p50_ms").unwrap_or(0.0),
                row.f64_of("p99_ms").unwrap_or(0.0),
            );
        }
    }
    if let Some(t) = m.get("trace") {
        println!(
            "trace: begun={} completed={} recorded={} anomalies={} \
             flush_mean={:.3}ms",
            t.usize_of("begun").unwrap_or(0),
            t.usize_of("completed").unwrap_or(0),
            t.usize_of("recorded").unwrap_or(0),
            t.usize_of("anomalies").unwrap_or(0),
            t.f64_of("flush_mean_ms").unwrap_or(0.0),
        );
    }
    if let Some(p) = m.get("proc") {
        println!(
            "proc: rss={:.1}MB cpu={:.2}s uptime={:.1}s fds={}",
            p.f64_of("rss_mb").unwrap_or(0.0),
            p.f64_of("cpu_s").unwrap_or(0.0),
            p.f64_of("uptime_s").unwrap_or(0.0),
            p.usize_of("open_fds").unwrap_or(0),
        );
    }

    // 4. Retained timelines + the anomaly slow log.
    let tr = c.trace(3)?;
    println!("\n{{\"cmd\":\"trace\",\"n\":3}} -> last timelines:");
    for span in tr.get("traces").and_then(|v| v.as_arr()).unwrap_or(&[]) {
        print_span(span);
    }
    println!("slow log (always-captured anomalies):");
    let slow = tr.get("slow").and_then(|v| v.as_arr()).unwrap_or(&[]);
    anyhow::ensure!(!slow.is_empty(), "the shed request must be in the slow log");
    for span in slow {
        print_span(span);
    }

    println!("\ntracing, metrics merge, and anomaly capture all round-tripped.");
    server.stop();
    Ok(())
}
