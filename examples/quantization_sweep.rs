//! Figure 4 driver: vector quantization — op-level win, graph-level loss.
//!
//! Paper: 8-bit quantization makes the convolutions ~25% faster, but the
//! inserted re-quantize / de-quantize ops cost more than the win — whole
//! inference slows by >100 ms.  This driver reproduces the accounting on
//! the fp32 vs quantized baseline graphs.
//!
//! ```bash
//! cargo run --release --example quantization_sweep -- [iters]
//! ```

use anyhow::Result;
use zuluko::bench::Bench;
use zuluko::engine::{build, EngineKind};
use zuluko::metrics::ledger::Group;
use zuluko::runtime::Manifest;
use zuluko::tensor::Tensor;

fn main() -> Result<()> {
    let iters: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);
    let manifest = Manifest::load(&zuluko::artifacts_dir())?;
    let input = Tensor::random(&[1, 227, 227, 3], 9);

    println!("== Figure 4 reproduction (iters={iters}) ==\n");

    // fp32 baseline graph.
    let mut tf = build(EngineKind::TfBaseline, &manifest)?;
    tf.warmup()?;
    tf.ledger_mut().clear();
    let tf_e2e = Bench::new("tf fp32")
        .warmup(1)
        .iters(iters)
        .run(|| {
            tf.infer(&input).expect("infer");
        });
    let n = (iters + 1) as f64;
    let tf_conv_ms: f64 = tf
        .ledger()
        .rows()
        .iter()
        .filter(|(name, g, _, _)| *g == Group::Group1 && is_conv(name))
        .map(|(_, _, _, ms)| ms)
        .sum::<f64>()
        / n;

    // Quantized graph.
    let mut q = build(EngineKind::Quant, &manifest)?;
    q.warmup()?;
    q.ledger_mut().clear();
    let q_e2e = Bench::new("tf quantized")
        .warmup(1)
        .iters(iters)
        .run(|| {
            q.infer(&input).expect("infer");
        });
    let q_conv_ms: f64 = q
        .ledger()
        .rows()
        .iter()
        .filter(|(name, g, _, _)| *g == Group::Group1 && is_conv(name))
        .map(|(_, _, _, ms)| ms)
        .sum::<f64>()
        / n;
    let q_overhead_ms = q.ledger().group_ms()[2] / n;

    println!("| quantity | fp32 | quant | delta | paper |");
    println!("|---|---|---|---|---|");
    println!(
        "| conv ops (ms/image) | {:.1} | {:.1} | {:+.0}% | -25% (conv alone) |",
        tf_conv_ms,
        q_conv_ms,
        (q_conv_ms / tf_conv_ms - 1.0) * 100.0
    );
    println!(
        "| q/dq overhead (ms/image) | 0.0 | {:.1} | +{:.1} ms | 'significant' |",
        q_overhead_ms, q_overhead_ms
    );
    println!(
        "| end-to-end (ms/image) | {:.1} | {:.1} | {:+.1} ms | >+100 ms slower |",
        tf_e2e.mean_ms,
        q_e2e.mean_ms,
        q_e2e.mean_ms - tf_e2e.mean_ms
    );

    println!();
    let conv_ratio = q_conv_ms / tf_conv_ms;
    println!("measured conv ratio (XLA-CPU int8/f32): {conv_ratio:.2}x");
    println!(
        "paper-scaled conv (NEON 8-bit SIMD, 0.80x of fp32): {:.1} ms — \
         overhead ({:.1} ms) {} the win ({:.1} ms)",
        tf_conv_ms * 0.80,
        q_overhead_ms,
        if q_overhead_ms > tf_conv_ms * 0.20 { "exceeds" } else { "does not exceed" },
        tf_conv_ms * 0.20
    );
    println!("\nconclusion check (paper): graph-surgery overhead outweighs the op win -> \
              quantization slows end-to-end inference on this class of engine");
    Ok(())
}

fn is_conv(name: &str) -> bool {
    // conv ops carry the site name; quantized raw convs end in `_q8`.
    name == "conv1"
        || name == "conv10"
        || name.ends_with("_squeeze")
        || name.ends_with("_expand1")
        || name.ends_with("_expand3")
        || name.ends_with("_q8")
}
