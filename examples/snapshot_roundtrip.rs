//! E17 CI smoke: replica-snapshot round trip against a persistent
//! directory (the CI `.zsnap` cache, see .github/workflows/ci.yml).
//!
//! Load-or-capture: if the directory already holds a valid snapshot
//! (written by an earlier CI job and restored from the cache), validate
//! and serve from it — proving cross-job durability of the format.  If
//! not (cold cache, or the format/content hash changed), capture one
//! from the deterministic synthetic artifacts and seed the cache.
//! Either way, build a sim replica from the snapshot and check one
//! inference against the sim oracle, so a snapshot that validated but
//! decoded wrong weights fails loudly.
//!
//! Run: cargo run --release --example snapshot_roundtrip [-- DIR]

use zuluko::engine::sim::expected_top1;
use zuluko::engine::{self, EngineKind};
use zuluko::runtime::{Manifest, ReplicaSnapshot};
use zuluko::tensor::image::Image;
use zuluko::tensor::Tensor;

const HW: usize = 64;
const CLASSES: usize = 1000;
const MODEL: &str = "squeezenet";

fn main() {
    let root = std::env::args().nth(1).unwrap_or_else(|| "ci-snapshots".into());
    let dir = std::path::PathBuf::from(root).join("squeezenet_sim");

    // Deterministic artifacts: identical bytes on every run, so the
    // content hash embedded in a cached snapshot stays valid across CI
    // jobs until the synthetic generator itself changes.
    if !dir.join("manifest.json").exists() {
        zuluko::testkit::manifest::write_synthetic(&dir, MODEL, CLASSES, HW, &[1, 2, 4])
            .expect("write synthetic artifacts");
        println!("seeded synthetic artifacts in {}", dir.display());
    }

    let snap = match ReplicaSnapshot::load(&dir) {
        Ok(snap) => {
            println!(
                "snapshot cache HIT: validated {} ({} resident bytes) against live artifacts",
                ReplicaSnapshot::path_for(&dir).display(),
                snap.resident_bytes()
            );
            snap
        }
        Err(e) => {
            println!("snapshot cache MISS ({e:#}); capturing");
            let m = Manifest::load(&dir).expect("manifest loads");
            let snap = ReplicaSnapshot::capture(&m, &[EngineKind::Sim]).expect("capture");
            snap.write(&dir).expect("atomic snapshot write");
            // Immediately re-load through the full validate path, so a
            // capture that writes an unloadable file fails this run, not
            // the next cached one.
            ReplicaSnapshot::load(&dir).expect("fresh snapshot re-loads")
        }
    };

    let mut eng =
        engine::build_from_snapshot(EngineKind::Sim, &snap).expect("replica from snapshot");
    if !snap.warm_covers(EngineKind::Sim) {
        eng.warmup().expect("warmup");
    }

    let img = Image::synthetic(HW, HW, 42);
    let mut buf = vec![0.0f32; HW * HW * 3];
    img.to_input_into(&mut buf);
    let want = expected_top1(MODEL, &buf, CLASSES);
    let out = eng
        .infer(&Tensor::new(&[1, HW, HW, 3], buf).unwrap())
        .expect("infer");
    let got = out.view().row(0).argmax();
    assert_eq!(
        got, want,
        "snapshot-built replica disagrees with the sim oracle"
    );
    println!("snapshot round-trip OK: top1 {got} matches the oracle");
}
