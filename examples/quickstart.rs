//! Quickstart: load the engine, classify one image, print top-5.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! cargo run --release --example quickstart -- path/to/image.ppm
//! ```

use anyhow::Result;
use zuluko::engine::{build, EngineKind};
use zuluko::runtime::Manifest;
use zuluko::tensor::image::Image;

fn main() -> Result<()> {
    // 1. Load the AOT artifacts (built once by `make artifacts`).
    let manifest = Manifest::load(&zuluko::artifacts_dir())?;
    println!(
        "model {} — {} params",
        manifest.model,
        manifest.params.iter().map(|p| p.nelems).sum::<usize>()
    );

    // 2. Build the from-scratch (ACL-style) engine and warm it up.
    let mut engine = build(EngineKind::AclStaged, &manifest)?;
    let t0 = std::time::Instant::now();
    engine.warmup()?;
    println!("engine ready in {:.1}s (compile included)", t0.elapsed().as_secs_f64());

    // 3. An image: a PPM from argv, or a synthetic frame.
    let img = match std::env::args().nth(1) {
        Some(path) => Image::load_ppm(std::path::Path::new(&path))?,
        None => Image::synthetic(640, 480, 42),
    };
    let input = img.to_input(); // center-crop + resize + scale to [-1,1]

    // 4. Infer.
    let t0 = std::time::Instant::now();
    let probs = engine.infer(&input)?;
    let ms = t0.elapsed().as_secs_f64() * 1e3;

    let row = probs.unstack()?.remove(0);
    println!("inference: {ms:.1} ms/image on `{}`", engine.name());
    for (rank, (class, p)) in row.topk(5).iter().enumerate() {
        println!("  #{} class {:<4} p={:.4}", rank + 1, class, p);
    }

    // 5. Where the time went (the paper's Fig 3 instrumentation).
    let [g1, g2, _, other] = engine.ledger().group_ms();
    println!("stage time: group1-ish {:.0} ms, group2-ish {:.0} ms, mixed {:.0} ms",
             g1, g2, other);
    Ok(())
}
